//! Client sampling — which of the population participates in each round.
//!
//! Two regimes:
//!
//! - **Enumerable** (`Uniform` / `RoundRobin`): the classic materialized
//!   path. Uniform sampling builds an index vector of the whole
//!   population, so it is guarded by [`MAX_ENUMERABLE_POPULATION`];
//!   round-robin window arithmetic is checked against `usize` overflow.
//!   Both guards surface as [`SamplerError`] — a typed error, not a
//!   panic — from [`Sampler::try_new`] / [`Sampler::try_sample`].
//! - **Population mode** ([`Sampler::for_population`]): cohorts are drawn
//!   by rejection sampling from a lazily-derived registered fleet
//!   (`fl::population`), with O(cohort) memory at 10^6–10^7 clients.

use crate::fl::population::{self, PopulationConfig, SampleStats};
use crate::util::rng::{hash_seed, Xoshiro256pp};

/// Uniform sampling materializes a `Vec<usize>` over the population;
/// beyond this bound (2^22 ≈ 4.2M clients ≈ 32 MiB of indices) the
/// config must use the lazy `[population]` mode instead.
pub const MAX_ENUMERABLE_POPULATION: usize = 1 << 22;

/// Typed sampling failures — the guards the 10^7-population regime needs
/// (an enumerable-path assumption violated, or an availability blackout
/// exhausting the rejection-sampling budget).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SamplerError {
    /// population of zero clients
    EmptyPopulation,
    /// `per_round` of zero clients
    ZeroPerRound,
    /// round-robin window arithmetic (`population * per_round`) would
    /// overflow `usize`
    CohortOverflow { population: usize, per_round: usize },
    /// uniform sampling would materialize an index vector over a
    /// population beyond [`MAX_ENUMERABLE_POPULATION`]
    PopulationTooLarge { population: usize, max: usize },
    /// population-mode rejection sampling hit its attempt cap before
    /// filling the cohort (availability blackout)
    AvailabilityExhausted {
        round: u64,
        wanted: usize,
        got: usize,
        attempts: u64,
    },
}

impl std::fmt::Display for SamplerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SamplerError::EmptyPopulation => {
                write!(f, "sampler needs a non-empty population")
            }
            SamplerError::ZeroPerRound => {
                write!(f, "per_round must be > 0")
            }
            SamplerError::CohortOverflow {
                population,
                per_round,
            } => write!(
                f,
                "round-robin window {population} * {per_round} overflows usize"
            ),
            SamplerError::PopulationTooLarge { population, max } => write!(
                f,
                "population {population} exceeds the enumerable bound {max}; \
                 use the lazy [population] mode"
            ),
            SamplerError::AvailabilityExhausted {
                round,
                wanted,
                got,
                attempts,
            } => write!(
                f,
                "round {round}: rejection sampling exhausted {attempts} \
                 attempts with {got}/{wanted} clients — availability blackout"
            ),
        }
    }
}

impl std::error::Error for SamplerError {}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplerKind {
    /// uniform without replacement (the paper's setting)
    Uniform,
    /// deterministic round-robin (useful for debugging/ablation)
    RoundRobin,
}

impl SamplerKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "uniform" => Ok(SamplerKind::Uniform),
            "round_robin" => Ok(SamplerKind::RoundRobin),
            other => anyhow::bail!("unknown sampler {other:?}"),
        }
    }
}

impl std::fmt::Display for SamplerKind {
    /// The canonical config spelling — `parse(x.to_string())` round-trips,
    /// and the sweep fingerprints use this form.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SamplerKind::Uniform => "uniform",
            SamplerKind::RoundRobin => "round_robin",
        })
    }
}

#[derive(Clone, Debug)]
pub struct Sampler {
    pub kind: SamplerKind,
    pub population: usize,
    pub per_round: usize,
    pub seed: u64,
    /// `Some` = lazy population mode: cohorts come from
    /// `population::sample_cohort` instead of the enumerable draws
    pub population_cfg: Option<PopulationConfig>,
}

impl Sampler {
    /// Build a sampler, panicking on invalid configs — the legacy entry
    /// point for code with statically-known-good parameters (tests,
    /// presets). Config-driven paths use [`try_new`](Self::try_new).
    pub fn new(kind: SamplerKind, population: usize, per_round: usize, seed: u64) -> Self {
        Self::try_new(kind, population, per_round, seed)
            .expect("sampler config")
    }

    /// Build a sampler. `per_round` is clamped to the population size —
    /// asking for a larger cohort than exists means full participation,
    /// not an error (stress configs legitimately over-ask). Enumerable
    /// guards: uniform populations beyond
    /// [`MAX_ENUMERABLE_POPULATION`] and round-robin window overflow are
    /// typed errors.
    pub fn try_new(
        kind: SamplerKind,
        population: usize,
        per_round: usize,
        seed: u64,
    ) -> Result<Self, SamplerError> {
        if population == 0 {
            return Err(SamplerError::EmptyPopulation);
        }
        if per_round == 0 {
            return Err(SamplerError::ZeroPerRound);
        }
        let per_round = per_round.min(population);
        match kind {
            SamplerKind::Uniform => {
                if population > MAX_ENUMERABLE_POPULATION {
                    return Err(SamplerError::PopulationTooLarge {
                        population,
                        max: MAX_ENUMERABLE_POPULATION,
                    });
                }
            }
            SamplerKind::RoundRobin => {
                if population.checked_mul(per_round).is_none() {
                    return Err(SamplerError::CohortOverflow {
                        population,
                        per_round,
                    });
                }
            }
        }
        Ok(Self {
            kind,
            population,
            per_round,
            seed,
            population_cfg: None,
        })
    }

    /// Lazy population mode: draw cohorts from `cfg.registered` clients
    /// by rejection sampling — no index vector, no enumerable bound.
    pub fn for_population(
        cfg: PopulationConfig,
        per_round: usize,
        seed: u64,
    ) -> Result<Self, SamplerError> {
        if cfg.registered == 0 {
            return Err(SamplerError::EmptyPopulation);
        }
        if per_round == 0 {
            return Err(SamplerError::ZeroPerRound);
        }
        Ok(Self {
            kind: SamplerKind::Uniform,
            population: cfg.registered,
            per_round: per_round.min(cfg.registered),
            seed,
            population_cfg: Some(cfg),
        })
    }

    /// Client ids participating in `round` (deterministic), with the
    /// population-mode rejection tallies when in lazy mode.
    pub fn try_sample_with_stats(
        &self,
        round: u64,
    ) -> Result<(Vec<usize>, Option<SampleStats>), SamplerError> {
        if let Some(cfg) = &self.population_cfg {
            let (ids, stats) = population::sample_cohort(
                cfg,
                self.seed,
                round,
                self.per_round,
            )?;
            return Ok((ids, Some(stats)));
        }
        let ids = match self.kind {
            SamplerKind::Uniform => {
                let mut rng = Xoshiro256pp::new(hash_seed(&[
                    self.seed, 0x5a3b1e, round,
                ]));
                let mut ids =
                    rng.sample_indices(self.population, self.per_round);
                ids.sort_unstable(); // stable ordering for reproducible logs
                ids
            }
            SamplerKind::RoundRobin => {
                // reduce the round first: same residue class, but the
                // product stays within one population of usize::MAX
                let base = round as usize % self.population;
                let start = base.checked_mul(self.per_round).ok_or(
                    SamplerError::CohortOverflow {
                        population: self.population,
                        per_round: self.per_round,
                    },
                )?;
                (0..self.per_round)
                    .map(|i| (start + i) % self.population)
                    .collect()
            }
        };
        Ok((ids, None))
    }

    /// [`try_sample_with_stats`](Self::try_sample_with_stats) without the
    /// tallies.
    pub fn try_sample(&self, round: u64) -> Result<Vec<usize>, SamplerError> {
        self.try_sample_with_stats(round).map(|(ids, _)| ids)
    }

    /// Client ids participating in `round` (deterministic). Panics on the
    /// typed failures — legacy entry point; engines use
    /// [`try_sample`](Self::try_sample).
    pub fn sample(&self, round: u64) -> Vec<usize> {
        self.try_sample(round).expect("sampler draw")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_distinct_and_in_range() {
        let s = Sampler::new(SamplerKind::Uniform, 64, 16, 1);
        for round in 0..50 {
            let ids = s.sample(round);
            assert_eq!(ids.len(), 16);
            let mut d = ids.clone();
            d.dedup();
            assert_eq!(d.len(), 16);
            assert!(ids.iter().all(|&i| i < 64));
        }
    }

    #[test]
    fn uniform_deterministic_but_varies_by_round() {
        let s = Sampler::new(SamplerKind::Uniform, 64, 16, 7);
        assert_eq!(s.sample(3), s.sample(3));
        assert_ne!(s.sample(3), s.sample(4));
    }

    #[test]
    fn uniform_covers_population() {
        let s = Sampler::new(SamplerKind::Uniform, 32, 8, 2);
        let mut seen = vec![false; 32];
        for round in 0..100 {
            for id in s.sample(round) {
                seen[id] = true;
            }
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn round_robin_cycles() {
        let s = Sampler::new(SamplerKind::RoundRobin, 6, 2, 0);
        assert_eq!(s.sample(0), vec![0, 1]);
        assert_eq!(s.sample(1), vec![2, 3]);
        assert_eq!(s.sample(2), vec![4, 5]);
        assert_eq!(s.sample(3), vec![0, 1]);
    }

    #[test]
    fn full_participation() {
        let s = Sampler::new(SamplerKind::Uniform, 8, 8, 3);
        let mut ids = s.sample(0);
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn per_round_larger_than_population_clamps() {
        // over-asking must degrade to full participation, not panic
        for kind in [SamplerKind::Uniform, SamplerKind::RoundRobin] {
            let s = Sampler::new(kind, 6, 100, 1);
            assert_eq!(s.per_round, 6);
            for round in 0..5 {
                let mut ids = s.sample(round);
                ids.sort_unstable();
                assert_eq!(ids, (0..6).collect::<Vec<_>>(), "{kind:?}");
            }
        }
    }

    #[test]
    fn round_robin_wraparound_is_deterministic() {
        // per_round does not divide the population: the window straddles
        // the wrap point and must replay exactly
        let s = Sampler::new(SamplerKind::RoundRobin, 5, 2, 9);
        assert_eq!(s.sample(0), vec![0, 1]);
        assert_eq!(s.sample(1), vec![2, 3]);
        assert_eq!(s.sample(2), vec![4, 0]);
        assert_eq!(s.sample(3), vec![1, 2]);
        // one full cycle of 5 rounds returns to the start
        assert_eq!(s.sample(5), s.sample(0));
        // independent instances with the same parameters agree
        let t = Sampler::new(SamplerKind::RoundRobin, 5, 2, 1234);
        for round in 0..10 {
            assert_eq!(s.sample(round), t.sample(round), "round {round}");
        }
        // huge round indices must not overflow into a panic
        let far = s.sample(u64::MAX / 256);
        assert_eq!(far.len(), 2);
        assert!(far.iter().all(|&i| i < 5));
    }

    #[test]
    fn uniform_reproducible_across_instances_under_fixed_seed() {
        let a = Sampler::new(SamplerKind::Uniform, 64, 16, 77);
        let b = Sampler::new(SamplerKind::Uniform, 64, 16, 77);
        let c = Sampler::new(SamplerKind::Uniform, 64, 16, 78);
        let mut any_diff = false;
        for round in 0..20 {
            assert_eq!(a.sample(round), b.sample(round), "round {round}");
            any_diff |= a.sample(round) != c.sample(round);
        }
        assert!(any_diff, "seed must actually enter the stream");
    }

    #[test]
    fn zero_population_and_zero_per_round_are_typed_errors() {
        assert_eq!(
            Sampler::try_new(SamplerKind::Uniform, 0, 4, 1).unwrap_err(),
            SamplerError::EmptyPopulation
        );
        assert_eq!(
            Sampler::try_new(SamplerKind::Uniform, 4, 0, 1).unwrap_err(),
            SamplerError::ZeroPerRound
        );
    }

    #[test]
    fn uniform_beyond_enumerable_bound_is_a_typed_error() {
        // 10^7 registered clients: the uniform path would materialize an
        // 80 MB index vector per draw — refused with a pointer to the
        // lazy mode, never a panic or an OOM
        let err = Sampler::try_new(SamplerKind::Uniform, 10_000_000, 64, 1)
            .unwrap_err();
        assert_eq!(
            err,
            SamplerError::PopulationTooLarge {
                population: 10_000_000,
                max: MAX_ENUMERABLE_POPULATION
            }
        );
        // ... while the bound itself is fine
        assert!(Sampler::try_new(
            SamplerKind::Uniform,
            MAX_ENUMERABLE_POPULATION,
            64,
            1
        )
        .is_ok());
        // round-robin never enumerates, so the same population is fine
        assert!(
            Sampler::try_new(SamplerKind::RoundRobin, 10_000_000, 64, 1)
                .is_ok()
        );
    }

    #[test]
    #[cfg(target_pointer_width = "64")]
    fn round_robin_window_overflow_is_a_typed_error() {
        // population * per_round > usize::MAX: the window start cannot be
        // computed — typed refusal at construction, not a wrapping panic
        let huge = 1usize << 33;
        assert!(matches!(
            Sampler::try_new(SamplerKind::RoundRobin, huge, huge, 0),
            Err(SamplerError::CohortOverflow { .. })
        ));
    }

    #[test]
    fn population_mode_samples_huge_fleets_lazily() {
        let cfg = PopulationConfig {
            enabled: true,
            registered: 10_000_000,
            ..PopulationConfig::default()
        };
        let s = Sampler::for_population(cfg, 32, 5).unwrap();
        let (ids, stats) = s.try_sample_with_stats(2).unwrap();
        assert_eq!(ids.len(), 32);
        assert!(stats.is_some(), "population mode must return tallies");
        let mut d = ids.clone();
        d.dedup();
        assert_eq!(d.len(), 32);
        assert!(ids.iter().all(|&i| i < 10_000_000));
        assert_eq!(s.try_sample(2).unwrap(), ids, "replay is exact");
        // classic paths return no tallies
        let classic = Sampler::new(SamplerKind::Uniform, 64, 8, 5);
        assert!(classic.try_sample_with_stats(0).unwrap().1.is_none());
    }

    #[test]
    fn population_mode_blackout_propagates_the_typed_error() {
        let cfg = PopulationConfig {
            enabled: true,
            registered: 4,
            churn_rate: 0.99,
            churn_period: 1,
            wave_amplitude: 0.99,
            wave_period: 2,
            ..PopulationConfig::default()
        };
        let s = Sampler::for_population(cfg, 4, 3).unwrap();
        let mut saw = false;
        for round in 0..8 {
            if let Err(SamplerError::AvailabilityExhausted { .. }) =
                s.try_sample(round)
            {
                saw = true;
            }
        }
        assert!(saw, "blackout must surface the typed error");
    }
}
