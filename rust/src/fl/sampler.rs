//! Client sampling — which of the population participates in each round.

use crate::util::rng::{hash_seed, Xoshiro256pp};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplerKind {
    /// uniform without replacement (the paper's setting)
    Uniform,
    /// deterministic round-robin (useful for debugging/ablation)
    RoundRobin,
}

impl SamplerKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "uniform" => Ok(SamplerKind::Uniform),
            "round_robin" => Ok(SamplerKind::RoundRobin),
            other => anyhow::bail!("unknown sampler {other:?}"),
        }
    }
}

impl std::fmt::Display for SamplerKind {
    /// The canonical config spelling — `parse(x.to_string())` round-trips,
    /// and the sweep fingerprints use this form.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SamplerKind::Uniform => "uniform",
            SamplerKind::RoundRobin => "round_robin",
        })
    }
}

#[derive(Clone, Debug)]
pub struct Sampler {
    pub kind: SamplerKind,
    pub population: usize,
    pub per_round: usize,
    pub seed: u64,
}

impl Sampler {
    /// Build a sampler. `per_round` is clamped to the population size —
    /// asking for a larger cohort than exists means full participation,
    /// not a panic (stress configs legitimately over-ask).
    pub fn new(kind: SamplerKind, population: usize, per_round: usize, seed: u64) -> Self {
        assert!(population > 0, "sampler needs a non-empty population");
        assert!(per_round > 0, "per_round must be > 0");
        Self {
            kind,
            population,
            per_round: per_round.min(population),
            seed,
        }
    }

    /// Client ids participating in `round` (deterministic).
    pub fn sample(&self, round: u64) -> Vec<usize> {
        match self.kind {
            SamplerKind::Uniform => {
                let mut rng = Xoshiro256pp::new(hash_seed(&[
                    self.seed, 0x5a3b1e, round,
                ]));
                let mut ids = rng.sample_indices(self.population, self.per_round);
                ids.sort_unstable(); // stable ordering for reproducible logs
                ids
            }
            SamplerKind::RoundRobin => (0..self.per_round)
                .map(|i| {
                    // reduce the round first: same residue class, but the
                    // product can never overflow for huge round indices
                    ((round as usize % self.population) * self.per_round + i)
                        % self.population
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_distinct_and_in_range() {
        let s = Sampler::new(SamplerKind::Uniform, 64, 16, 1);
        for round in 0..50 {
            let ids = s.sample(round);
            assert_eq!(ids.len(), 16);
            let mut d = ids.clone();
            d.dedup();
            assert_eq!(d.len(), 16);
            assert!(ids.iter().all(|&i| i < 64));
        }
    }

    #[test]
    fn uniform_deterministic_but_varies_by_round() {
        let s = Sampler::new(SamplerKind::Uniform, 64, 16, 7);
        assert_eq!(s.sample(3), s.sample(3));
        assert_ne!(s.sample(3), s.sample(4));
    }

    #[test]
    fn uniform_covers_population() {
        let s = Sampler::new(SamplerKind::Uniform, 32, 8, 2);
        let mut seen = vec![false; 32];
        for round in 0..100 {
            for id in s.sample(round) {
                seen[id] = true;
            }
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn round_robin_cycles() {
        let s = Sampler::new(SamplerKind::RoundRobin, 6, 2, 0);
        assert_eq!(s.sample(0), vec![0, 1]);
        assert_eq!(s.sample(1), vec![2, 3]);
        assert_eq!(s.sample(2), vec![4, 5]);
        assert_eq!(s.sample(3), vec![0, 1]);
    }

    #[test]
    fn full_participation() {
        let s = Sampler::new(SamplerKind::Uniform, 8, 8, 3);
        let mut ids = s.sample(0);
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn per_round_larger_than_population_clamps() {
        // over-asking must degrade to full participation, not panic
        for kind in [SamplerKind::Uniform, SamplerKind::RoundRobin] {
            let s = Sampler::new(kind, 6, 100, 1);
            assert_eq!(s.per_round, 6);
            for round in 0..5 {
                let mut ids = s.sample(round);
                ids.sort_unstable();
                assert_eq!(ids, (0..6).collect::<Vec<_>>(), "{kind:?}");
            }
        }
    }

    #[test]
    fn round_robin_wraparound_is_deterministic() {
        // per_round does not divide the population: the window straddles
        // the wrap point and must replay exactly
        let s = Sampler::new(SamplerKind::RoundRobin, 5, 2, 9);
        assert_eq!(s.sample(0), vec![0, 1]);
        assert_eq!(s.sample(1), vec![2, 3]);
        assert_eq!(s.sample(2), vec![4, 0]);
        assert_eq!(s.sample(3), vec![1, 2]);
        // one full cycle of 5 rounds returns to the start
        assert_eq!(s.sample(5), s.sample(0));
        // independent instances with the same parameters agree
        let t = Sampler::new(SamplerKind::RoundRobin, 5, 2, 1234);
        for round in 0..10 {
            assert_eq!(s.sample(round), t.sample(round), "round {round}");
        }
        // huge round indices must not overflow into a panic
        let far = s.sample(u64::MAX / 256);
        assert_eq!(far.len(), 2);
        assert!(far.iter().all(|&i| i < 5));
    }

    #[test]
    fn uniform_reproducible_across_instances_under_fixed_seed() {
        let a = Sampler::new(SamplerKind::Uniform, 64, 16, 77);
        let b = Sampler::new(SamplerKind::Uniform, 64, 16, 77);
        let c = Sampler::new(SamplerKind::Uniform, 64, 16, 78);
        let mut any_diff = false;
        for round in 0..20 {
            assert_eq!(a.sample(round), b.sample(round), "round {round}");
            any_diff |= a.sample(round) != c.sample(round);
        }
        assert!(any_diff, "seed must actually enter the stream");
    }
}
