//! Client sampling — which of the population participates in each round.

use crate::util::rng::{hash_seed, Xoshiro256pp};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplerKind {
    /// uniform without replacement (the paper's setting)
    Uniform,
    /// deterministic round-robin (useful for debugging/ablation)
    RoundRobin,
}

impl SamplerKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "uniform" => Ok(SamplerKind::Uniform),
            "round_robin" => Ok(SamplerKind::RoundRobin),
            other => anyhow::bail!("unknown sampler {other:?}"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct Sampler {
    pub kind: SamplerKind,
    pub population: usize,
    pub per_round: usize,
    pub seed: u64,
}

impl Sampler {
    pub fn new(kind: SamplerKind, population: usize, per_round: usize, seed: u64) -> Self {
        assert!(per_round > 0 && per_round <= population);
        Self {
            kind,
            population,
            per_round,
            seed,
        }
    }

    /// Client ids participating in `round` (deterministic).
    pub fn sample(&self, round: u64) -> Vec<usize> {
        match self.kind {
            SamplerKind::Uniform => {
                let mut rng = Xoshiro256pp::new(hash_seed(&[
                    self.seed, 0x5a3b1e, round,
                ]));
                let mut ids = rng.sample_indices(self.population, self.per_round);
                ids.sort_unstable(); // stable ordering for reproducible logs
                ids
            }
            SamplerKind::RoundRobin => (0..self.per_round)
                .map(|i| {
                    (round as usize * self.per_round + i) % self.population
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_distinct_and_in_range() {
        let s = Sampler::new(SamplerKind::Uniform, 64, 16, 1);
        for round in 0..50 {
            let ids = s.sample(round);
            assert_eq!(ids.len(), 16);
            let mut d = ids.clone();
            d.dedup();
            assert_eq!(d.len(), 16);
            assert!(ids.iter().all(|&i| i < 64));
        }
    }

    #[test]
    fn uniform_deterministic_but_varies_by_round() {
        let s = Sampler::new(SamplerKind::Uniform, 64, 16, 7);
        assert_eq!(s.sample(3), s.sample(3));
        assert_ne!(s.sample(3), s.sample(4));
    }

    #[test]
    fn uniform_covers_population() {
        let s = Sampler::new(SamplerKind::Uniform, 32, 8, 2);
        let mut seen = vec![false; 32];
        for round in 0..100 {
            for id in s.sample(round) {
                seen[id] = true;
            }
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn round_robin_cycles() {
        let s = Sampler::new(SamplerKind::RoundRobin, 6, 2, 0);
        assert_eq!(s.sample(0), vec![0, 1]);
        assert_eq!(s.sample(1), vec![2, 3]);
        assert_eq!(s.sample(2), vec![4, 5]);
        assert_eq!(s.sample(3), vec![0, 1]);
    }

    #[test]
    fn full_participation() {
        let s = Sampler::new(SamplerKind::Uniform, 8, 8, 3);
        let mut ids = s.sample(0);
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
    }
}
