//! Deterministic chaos engine: planned wire faults, retries, and quarantine.
//!
//! Production cross-device links do not merely *lose* clients (that is
//! `fl::cohort`'s dropout/straggler model) — they corrupt bytes, replay
//! frames, crash devices mid-round, and bounce server-side commits. This
//! module injects exactly those faults, but *deterministically*: every
//! fault is drawn up front from an RNG stream keyed by
//! `(seed, CHAOS_STREAM, round, cid)` — the same keying discipline as
//! [`plan_cohort`](super::cohort::plan_cohort) — so the same seed produces
//! the same faults, the same retries, and therefore the same committed
//! bytes at any worker count. Retry backoff is *virtual time*: it shifts a
//! client's simulated latency (sync deadline math, async arrival order)
//! without any wall-clock sleep.
//!
//! Fault taxonomy (see `docs/ROBUSTNESS.md`):
//!
//! * **Bit-flip / truncation** — an uplink attempt is corrupted; the v2
//!   wire CRCs reject it and the client retries with exponential backoff,
//!   up to [`ChaosConfig::max_retries`] times. A client whose every
//!   attempt is corrupt *gives up* (fate
//!   [`Crashed`](super::cohort::ClientFate::Crashed)): its bytes were
//!   spent and accounted as rejected, but nothing aggregates.
//! * **Duplicate** — the accepted frame is replayed; the server's
//!   [`NonceLedger`](crate::omc::codec::NonceLedger) rejects the replay.
//! * **Crash** — the client dies after its downlink, before training.
//! * **Commit failure** — a server-side commit transiently fails and is
//!   retried after virtual-time backoff (async engine only; a sync round
//!   has no separate commit step).
//!
//! Repeated offenders climb a **quarantine ladder**: a client that ships
//! [`ChaosConfig::quarantine_threshold`] consecutive corrupt frames is
//! excluded from sampling for [`ChaosConfig::quarantine_rounds`] rounds,
//! then re-admitted with a clean slate.

use std::collections::BTreeMap;

use crate::util::rng::{hash_seed, Xoshiro256pp};

/// Stream tag for all chaos draws (cf. `0xFA7E5` for cohort fates).
const CHAOS_STREAM: u64 = 0xC4A05;

/// Knobs of the fault-injection model (all off by default). Surfaced as
/// the `[chaos]` TOML table; `enabled = true` requires `omc.integrity`
/// (corrupt frames must be *detectable* to be rejected).
#[derive(Clone, Copy, Debug)]
pub struct ChaosConfig {
    /// Master switch; when off, the engines skip all chaos planning.
    pub enabled: bool,
    /// Per-attempt probability an uplink frame suffers a single-bit flip.
    pub bitflip_prob: f64,
    /// Per-attempt probability an uplink frame is truncated.
    pub truncate_prob: f64,
    /// Probability the accepted uplink is duplicated (replayed) once.
    pub duplicate_prob: f64,
    /// Probability a client crashes after its downlink, before training.
    pub crash_prob: f64,
    /// Per-attempt probability a server-side commit transiently fails.
    pub commit_failure_prob: f64,
    /// Retries granted after a corrupt attempt (so a client sends at most
    /// `max_retries + 1` frames per round).
    pub max_retries: u32,
    /// Base of the exponential virtual-time backoff: retry `k` waits
    /// `backoff_base_s * 2^k` simulated seconds.
    pub backoff_base_s: f64,
    /// Consecutive corrupt frames that trigger quarantine.
    pub quarantine_threshold: u32,
    /// Rounds a quarantined client is excluded from sampling.
    pub quarantine_rounds: u64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            bitflip_prob: 0.0,
            truncate_prob: 0.0,
            duplicate_prob: 0.0,
            crash_prob: 0.0,
            commit_failure_prob: 0.0,
            max_retries: 2,
            backoff_base_s: 0.5,
            quarantine_threshold: 3,
            quarantine_rounds: 2,
        }
    }
}

impl ChaosConfig {
    /// True when no chaos planning should run at all.
    pub fn is_off(&self) -> bool {
        !self.enabled
    }

    /// Bounds-check the knobs (called by `ExperimentConfig::validate`).
    pub fn validate(&self) -> anyhow::Result<()> {
        for (name, p) in [
            ("chaos.bitflip", self.bitflip_prob),
            ("chaos.truncate", self.truncate_prob),
            ("chaos.duplicate", self.duplicate_prob),
            ("chaos.crash", self.crash_prob),
            ("chaos.commit_failure", self.commit_failure_prob),
        ] {
            anyhow::ensure!(
                (0.0..1.0).contains(&p),
                "{name} must be in [0, 1), got {p}"
            );
        }
        anyhow::ensure!(
            self.bitflip_prob + self.truncate_prob < 1.0,
            "chaos.bitflip + chaos.truncate must stay below 1.0"
        );
        anyhow::ensure!(
            self.max_retries <= 16,
            "chaos.max_retries must be <= 16 (backoff is 2^k)"
        );
        anyhow::ensure!(
            self.backoff_base_s >= 0.0 && self.backoff_base_s.is_finite(),
            "chaos.backoff_base_s must be finite and >= 0"
        );
        anyhow::ensure!(
            self.quarantine_threshold >= 1,
            "chaos.quarantine_threshold must be >= 1"
        );
        anyhow::ensure!(
            self.quarantine_rounds >= 1,
            "chaos.quarantine_rounds must be >= 1"
        );
        Ok(())
    }

    /// A copy with the fault/crash probabilities scaled by a device-class
    /// multiplier (`fl::population`), clamped so every scaled value still
    /// validates. Retry/backoff/quarantine knobs are untouched, and the
    /// per-client draw count never changes — scaling moves thresholds,
    /// not streams, so A/B comparisons against the unscaled config see
    /// identical RNG sequences.
    pub fn scaled(&self, fault_mult: f64) -> Self {
        let clamp = |p: f64| (p * fault_mult).min(0.999_999);
        let mut out = *self;
        out.bitflip_prob = clamp(self.bitflip_prob);
        out.truncate_prob = clamp(self.truncate_prob);
        // the corrupt-attempt split must stay a sub-probability pair
        let corrupt = out.bitflip_prob + out.truncate_prob;
        if corrupt >= 1.0 {
            let shrink = 0.999_999 / corrupt;
            out.bitflip_prob *= shrink;
            out.truncate_prob *= shrink;
        }
        out.duplicate_prob = clamp(self.duplicate_prob);
        out.crash_prob = clamp(self.crash_prob);
        out.commit_failure_prob = clamp(self.commit_failure_prob);
        out
    }
}

/// How one uplink attempt is corrupted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Flip a single bit (position derived from the planned parameter).
    BitFlip,
    /// Truncate the frame to a shorter prefix.
    Truncate,
}

/// One planned corrupt uplink attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlannedFault {
    /// What happens to the frame.
    pub kind: FaultKind,
    /// Raw 64-bit draw; [`apply_fault`] maps it onto the frame's length
    /// (bit index or cut point) at execution time.
    pub param: u64,
}

/// Everything chaos does to one client in one round, decided before any
/// training runs — which is what keeps execution order irrelevant.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClientChaos {
    /// Client dies after the downlink: no training, no uplink.
    pub crashed: bool,
    /// Corrupt attempts, in send order, before the clean delivery (or
    /// before giving up).
    pub faults: Vec<PlannedFault>,
    /// All `max_retries + 1` attempts were corrupt: the update never
    /// lands, every attempt's bytes are rejected.
    pub gave_up: bool,
    /// The accepted frame is replayed once (rejected by the nonce ledger).
    pub duplicate: bool,
    /// Virtual-time backoff added to the client's latency by its retries:
    /// `Σ backoff_base_s · 2^k` over the corrupt attempts.
    pub extra_latency_s: f64,
}

impl ClientChaos {
    /// True when chaos leaves this client entirely alone.
    pub fn is_clean(&self) -> bool {
        !self.crashed && self.faults.is_empty() && !self.duplicate
    }

    /// Frames this client sends that the server must reject: the corrupt
    /// attempts plus the duplicate replay (crashed clients send nothing).
    pub fn rejected_frames(&self) -> u64 {
        if self.crashed {
            return 0;
        }
        self.faults.len() as u64 + u64::from(self.duplicate && !self.gave_up)
    }
}

/// Draw the deterministic fault plan for one client in one round.
///
/// Every knob consumes its RNG draws unconditionally (the same discipline
/// as `plan_cohort`), so toggling one fault class never reshuffles the
/// draws of another — A/B chaos scenarios at the same seed stay aligned.
pub fn plan_client(cfg: &ChaosConfig, seed: u64, round: u64, cid: usize) -> ClientChaos {
    let mut rng = Xoshiro256pp::new(hash_seed(&[
        seed,
        CHAOS_STREAM,
        round,
        cid as u64,
    ]));
    let u_crash = rng.next_f64();
    let corrupt_prob = cfg.bitflip_prob + cfg.truncate_prob;
    let mut faults = Vec::new();
    let mut gave_up = true;
    let mut extra_latency_s = 0.0;
    for attempt in 0..=cfg.max_retries {
        let u_fault = rng.next_f64();
        let u_kind = rng.next_f64();
        let param = rng.next_u64();
        // keep drawing even after the clean attempt so the duplicate draw
        // below sits at a fixed stream position for every retry outcome
        if gave_up && u_fault < corrupt_prob {
            let kind = if u_kind * corrupt_prob < cfg.bitflip_prob {
                FaultKind::BitFlip
            } else {
                FaultKind::Truncate
            };
            faults.push(PlannedFault { kind, param });
            extra_latency_s += cfg.backoff_base_s * f64::from(1u32 << attempt.min(16));
        } else {
            gave_up = false;
        }
    }
    let u_dup = rng.next_f64();
    ClientChaos {
        crashed: u_crash < cfg.crash_prob,
        duplicate: u_dup < cfg.duplicate_prob,
        faults,
        gave_up,
        extra_latency_s,
    }
}

/// Corrupt a wire frame in place according to a planned fault. Frames
/// shorter than two bytes are left alone (nothing meaningful to corrupt).
pub fn apply_fault(fault: &PlannedFault, frame: &mut Vec<u8>) {
    if frame.len() < 2 {
        return;
    }
    match fault.kind {
        FaultKind::BitFlip => {
            let bit = (fault.param % (frame.len() as u64 * 8)) as usize;
            frame[bit / 8] ^= 1 << (bit % 8);
        }
        FaultKind::Truncate => {
            let cut = 1 + (fault.param % (frame.len() as u64 - 1)) as usize;
            frame.truncate(cut);
        }
    }
}

/// One client's chaos facts from a round, consumed by the [`Quarantine`]
/// ladder. Entirely plan-time computable, so the ladder's evolution is
/// deterministic no matter how the round executed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChaosClientReport {
    /// the client
    pub cid: usize,
    /// corrupt frames the server rejected from it this round
    pub corrupt_frames: u32,
    /// whether a clean, accepted frame eventually landed (resets strikes
    /// when the ladder was not already tripped)
    pub delivered_clean: bool,
}

/// Planned transient failures for one server-side commit.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommitChaos {
    /// Consecutive failed commit attempts before the one that sticks
    /// (capped at `max_retries`; the final attempt always succeeds, so a
    /// commit is delayed, never lost).
    pub failures: u32,
    /// Virtual-time delay those retries add to the commit.
    pub delay_s: f64,
}

/// Draw the deterministic transient-failure plan for commit `idx`.
pub fn plan_commit(cfg: &ChaosConfig, seed: u64, idx: u64) -> CommitChaos {
    let mut rng = Xoshiro256pp::new(hash_seed(&[
        seed,
        CHAOS_STREAM,
        0xC0331A,
        idx,
    ]));
    let mut failures = 0u32;
    let mut still_failing = true;
    for _ in 0..cfg.max_retries {
        let u = rng.next_f64();
        // unconditional draws keep the stream aligned across prob changes
        if still_failing && u < cfg.commit_failure_prob {
            failures += 1;
        } else {
            still_failing = false;
        }
    }
    let mut delay_s = 0.0;
    for k in 0..failures {
        delay_s += cfg.backoff_base_s * f64::from(1u32 << k.min(16));
    }
    CommitChaos { failures, delay_s }
}

/// Per-client quarantine ladder: consecutive corrupt frames accumulate
/// *strikes*; at [`ChaosConfig::quarantine_threshold`] the client is
/// excluded from sampling for [`ChaosConfig::quarantine_rounds`] rounds,
/// then re-admitted with zero strikes. A clean delivery below the
/// threshold also resets the count ("consecutive", not "total").
///
/// `BTreeMap`s keep iteration — and therefore
/// [`quarantined_at`](Self::quarantined_at) — deterministic.
#[derive(Clone, Debug, Default)]
pub struct Quarantine {
    strikes: BTreeMap<usize, u32>,
    until: BTreeMap<usize, u64>,
}

impl Quarantine {
    /// Fresh ladder with no strikes and nobody quarantined.
    pub fn new() -> Self {
        Self::default()
    }

    /// True when `cid` must be excluded from sampling in `round`.
    pub fn is_quarantined(&self, cid: usize, round: u64) -> bool {
        self.until.get(&cid).map_or(false, |&r| round < r)
    }

    /// All clients quarantined in `round`, ascending — the engines filter
    /// the sampler's output against this list.
    pub fn quarantined_at(&self, round: u64) -> Vec<usize> {
        self.until
            .iter()
            .filter(|&(_, &r)| round < r)
            .map(|(&cid, _)| cid)
            .collect()
    }

    /// Record one client-round: `corrupt_frames` strikes, then — if the
    /// round ended in a clean, accepted delivery and the ladder was not
    /// tripped — a reset. Returns true when this call quarantines `cid`
    /// (from the end of `round` until `round + 1 + quarantine_rounds`).
    pub fn record(
        &mut self,
        cfg: &ChaosConfig,
        cid: usize,
        corrupt_frames: u32,
        delivered_clean: bool,
        round: u64,
    ) -> bool {
        let strikes = self.strikes.entry(cid).or_insert(0);
        *strikes += corrupt_frames;
        if *strikes >= cfg.quarantine_threshold {
            self.strikes.remove(&cid);
            self.until
                .insert(cid, round + 1 + cfg.quarantine_rounds);
            return true;
        }
        if delivered_clean {
            self.strikes.remove(&cid);
        }
        false
    }

    /// Number of clients currently holding a quarantine sentence that ends
    /// after `round` (monitoring/metrics).
    pub fn active(&self, round: u64) -> usize {
        self.until.values().filter(|&&r| round < r).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn noisy() -> ChaosConfig {
        ChaosConfig {
            enabled: true,
            bitflip_prob: 0.2,
            truncate_prob: 0.1,
            duplicate_prob: 0.15,
            crash_prob: 0.1,
            commit_failure_prob: 0.2,
            ..ChaosConfig::default()
        }
    }

    #[test]
    fn plans_are_deterministic_and_keyed() {
        let cfg = noisy();
        let a = plan_client(&cfg, 42, 3, 7);
        let b = plan_client(&cfg, 42, 3, 7);
        assert_eq!(a, b);
        // at least one of round/cid/seed must change the plan somewhere
        let mut differs = false;
        for (seed, round, cid) in [(42, 3, 8), (42, 4, 7), (43, 3, 7)] {
            differs |= plan_client(&cfg, seed, round, cid) != a;
        }
        assert!(differs);
        assert_eq!(plan_commit(&cfg, 42, 5), plan_commit(&cfg, 42, 5));
    }

    #[test]
    fn zero_probs_mean_no_chaos() {
        let cfg = ChaosConfig { enabled: true, ..ChaosConfig::default() };
        for cid in 0..50 {
            let p = plan_client(&cfg, 1, 0, cid);
            assert!(p.is_clean(), "{p:?}");
            assert!(!p.gave_up);
            assert_eq!(p.extra_latency_s, 0.0);
            assert_eq!(p.rejected_frames(), 0);
        }
        assert_eq!(plan_commit(&cfg, 1, 9), CommitChaos::default());
        assert!(ChaosConfig::default().is_off());
    }

    #[test]
    fn certain_corruption_exhausts_retries_and_gives_up() {
        let cfg = ChaosConfig {
            enabled: true,
            bitflip_prob: 0.9999,
            max_retries: 2,
            backoff_base_s: 0.5,
            ..ChaosConfig::default()
        };
        let p = plan_client(&cfg, 7, 1, 3);
        assert!(p.gave_up);
        assert_eq!(p.faults.len(), 3); // initial attempt + 2 retries
        // backoff sum: 0.5·(1 + 2 + 4)
        assert!((p.extra_latency_s - 3.5).abs() < 1e-12);
        assert_eq!(p.rejected_frames(), 3); // duplicate moot after give-up
        assert!(p.faults.iter().all(|f| f.kind == FaultKind::BitFlip));
    }

    #[test]
    fn fault_rates_are_statistically_right() {
        let cfg = noisy();
        let (mut crashed, mut corrupt_first, mut dup) = (0u32, 0u32, 0u32);
        let trials = 4_000u64;
        for i in 0..trials {
            let p = plan_client(&cfg, 11, i, (i % 64) as usize);
            crashed += u32::from(p.crashed);
            corrupt_first += u32::from(!p.faults.is_empty());
            dup += u32::from(p.duplicate);
        }
        let rate = |c: u32| c as f64 / trials as f64;
        assert!((rate(crashed) - 0.1).abs() < 0.02, "{}", rate(crashed));
        assert!(
            (rate(corrupt_first) - 0.3).abs() < 0.03,
            "{}",
            rate(corrupt_first)
        );
        assert!((rate(dup) - 0.15).abs() < 0.02, "{}", rate(dup));
    }

    #[test]
    fn fault_class_toggles_do_not_reshuffle_other_draws() {
        let base = noisy();
        let no_crash = ChaosConfig { crash_prob: 0.0, ..base };
        for i in 0..200u64 {
            let a = plan_client(&base, 5, i, 3);
            let b = plan_client(&no_crash, 5, i, 3);
            assert_eq!(a.faults, b.faults, "round {i}");
            assert_eq!(a.duplicate, b.duplicate, "round {i}");
        }
    }

    #[test]
    fn apply_fault_flips_one_bit_or_truncates() {
        let frame: Vec<u8> = (0..64).collect();
        let flip = PlannedFault { kind: FaultKind::BitFlip, param: 999 };
        let mut a = frame.clone();
        apply_fault(&flip, &mut a);
        assert_eq!(a.len(), frame.len());
        let flipped: u32 = a
            .iter()
            .zip(&frame)
            .map(|(x, y)| (x ^ y).count_ones())
            .sum();
        assert_eq!(flipped, 1);

        let cut = PlannedFault { kind: FaultKind::Truncate, param: 7777 };
        let mut b = frame.clone();
        apply_fault(&cut, &mut b);
        assert!(!b.is_empty() && b.len() < frame.len());
        assert_eq!(&frame[..b.len()], &b[..]);

        // degenerate frames are left alone
        let mut tiny = vec![1u8];
        apply_fault(&cut, &mut tiny);
        assert_eq!(tiny, vec![1u8]);
    }

    #[test]
    fn commit_failures_are_capped_and_delayed() {
        let cfg = ChaosConfig {
            enabled: true,
            commit_failure_prob: 0.9999,
            max_retries: 3,
            backoff_base_s: 1.0,
            ..ChaosConfig::default()
        };
        let c = plan_commit(&cfg, 2, 0);
        assert_eq!(c.failures, 3);
        assert!((c.delay_s - 7.0).abs() < 1e-12); // 1 + 2 + 4
        let calm = ChaosConfig {
            commit_failure_prob: 0.0,
            ..cfg
        };
        assert_eq!(plan_commit(&calm, 2, 0), CommitChaos::default());
    }

    #[test]
    fn quarantine_ladder_trips_resets_and_expires() {
        let cfg = ChaosConfig {
            enabled: true,
            quarantine_threshold: 3,
            quarantine_rounds: 2,
            ..ChaosConfig::default()
        };
        let mut q = Quarantine::new();
        // two strikes, then a clean delivery: reset
        assert!(!q.record(&cfg, 7, 2, true, 0));
        assert!(!q.record(&cfg, 7, 2, true, 1));
        assert!(!q.is_quarantined(7, 2));
        // three consecutive corrupt frames in one round: tripped
        assert!(q.record(&cfg, 7, 3, false, 2));
        assert!(q.is_quarantined(7, 3));
        assert!(q.is_quarantined(7, 4));
        assert!(!q.is_quarantined(7, 5), "sentence must expire");
        assert_eq!(q.quarantined_at(3), vec![7]);
        assert_eq!(q.active(3), 1);
        assert_eq!(q.active(5), 0);
        // strikes accumulate across gave-up rounds without clean resets
        assert!(!q.record(&cfg, 8, 1, false, 0));
        assert!(!q.record(&cfg, 8, 1, false, 1));
        assert!(q.record(&cfg, 8, 1, false, 2));
        // a fresh sentence starts with a clean slate afterwards
        assert!(!q.record(&cfg, 8, 2, true, 5));
        assert!(!q.is_quarantined(8, 6));
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        noisy().validate().unwrap();
        ChaosConfig::default().validate().unwrap();
        let ok = noisy();
        for bad in [
            ChaosConfig { bitflip_prob: 1.0, ..ok },
            ChaosConfig { truncate_prob: -0.1, ..ok },
            ChaosConfig { bitflip_prob: 0.6, truncate_prob: 0.5, ..ok },
            ChaosConfig { crash_prob: 1.5, ..ok },
            ChaosConfig { commit_failure_prob: 1.0, ..ok },
            ChaosConfig { max_retries: 17, ..ok },
            ChaosConfig { backoff_base_s: f64::NAN, ..ok },
            ChaosConfig { backoff_base_s: -1.0, ..ok },
            ChaosConfig { quarantine_threshold: 0, ..ok },
            ChaosConfig { quarantine_rounds: 0, ..ok },
        ] {
            assert!(bad.validate().is_err(), "{bad:?}");
        }
    }
}
