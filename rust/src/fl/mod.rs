//! Federated-learning substrate: server state + aggregation (reference and
//! streaming paths), simulated clients, cohort failure scenarios, the
//! deterministic fault-injection engine ([`chaos`]), client sampling,
//! the lazy million-client population engine ([`population`]),
//! synchronous round orchestration, the buffered staleness-aware
//! asynchronous engine ([`async_round`]), and the wall-clock
//! multi-threaded serving engine ([`serve`]).

pub mod async_round;
pub mod chaos;
pub mod client;
pub mod cohort;
pub mod population;
pub mod round;
pub mod sampler;
pub mod serve;
pub mod server;
