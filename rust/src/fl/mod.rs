//! Federated-learning substrate: server state + aggregation, simulated
//! clients, client sampling, and round orchestration.

pub mod client;
pub mod round;
pub mod sampler;
pub mod server;
