//! Federated-learning substrate: server state + aggregation (reference and
//! streaming paths), simulated clients, cohort failure scenarios, client
//! sampling, and round orchestration.

pub mod client;
pub mod cohort;
pub mod round;
pub mod sampler;
pub mod server;
