//! **Population engine** — O(active)-memory simulation of 10^6–10^7
//! registered clients (`docs/SCALE.md`).
//!
//! The sweep grid used to materialize per-client state (dataset shards,
//! sampler index vectors), capping a cell at toy populations. This module
//! derives everything lazily from `(seed, cid)` with the same
//! [`hash_seed`] keying every other stochastic decision uses, so a
//! million-client fleet costs exactly as much memory as the cohort that
//! actually trains this round:
//!
//! - **Device classes** — a fixed four-rung ladder (flagship / mid /
//!   budget / iot) assigned per client by a weighted draw from the
//!   client's profile stream. Each class carries latency / dropout /
//!   fault multipliers that scale the existing `cohort` and `chaos`
//!   draws *after* the uniform variates are taken, so A/B stream
//!   alignment survives (`docs/ROBUSTNESS.md`).
//! - **Churn** — a `churn_rate` fraction of clients are churners that
//!   duty-cycle over join/leave epochs of `churn_period` rounds: each
//!   churner is registered for [`CHURN_DUTY`] out of every
//!   [`CHURN_CYCLE`] epochs, phase-shifted per client.
//! - **Diurnal waves** — availability dips follow a piecewise-linear
//!   triangle wave over `wave_period` rounds, phase-shifted per device
//!   class. A triangle (not a sine) keeps the whole model in exact
//!   rational arithmetic: no `libm` call whose last bit could differ
//!   across platforms ever gates a sampling decision.
//! - **Rejection sampling** — [`sample_cohort`] draws candidate cids
//!   uniformly from the registered range and rejects unavailable ones;
//!   cost is O(k / availability), independent of the registered count.
//!   Validation bounds availability away from zero, and a hard attempt
//!   cap converts pathological configs into a typed error instead of a
//!   hang.
//! - **Two-tier topology** — [`encode_edge_frame`] / [`decode_edge_frame`]
//!   carry an edge aggregator's weighted f64 sums, cast to f32, to the
//!   root in the ordinary wire format (v2 integrity framing and XOR-delta
//!   against the previous round's payload both supported). Shipping
//!   *sums* rather than means makes the single-edge topology bit-exact
//!   against flat aggregation: `f32(S)` survives the f32→f64→f32 round
//!   trip unchanged.
//!
//! Everything here is a pure function of `(config, seed, round, cid)` —
//! no state, no iteration order, no wall clock — so the byte-identical
//! summary contract holds at any worker count.

use crate::fl::sampler::SamplerError;
use crate::fl::server::StreamingAggregator;
use crate::omc::codec::{self, WireWriter};
use crate::omc::delta::{xor_decode_into, xor_encode_into};
use crate::util::rng::{hash_seed, SplitMix64, Xoshiro256pp};

/// Stream tag: cohort rejection sampling (per round).
pub const SAMPLE_STREAM: u64 = 0x5CA1E5;
/// Stream tag: per-client device profile (class, churn phase).
pub const PROFILE_STREAM: u64 = 0xC1A55;
/// Stream tag: per-(round, cid) diurnal availability gate.
pub const WAVE_STREAM: u64 = 0x0D1_02_4A1;
/// Stream tag: edge→root frame nonces.
pub const EDGE_NONCE_STREAM: u64 = 0xED6E;

/// Churner duty cycle: active [`CHURN_DUTY`] of every [`CHURN_CYCLE`]
/// epochs (an epoch is `churn_period` rounds).
pub const CHURN_CYCLE: u64 = 4;
/// See [`CHURN_CYCLE`].
pub const CHURN_DUTY: u64 = 2;

/// Rejection-sampling attempt budget per requested client. With
/// availability bounded below by `(1 - wave_amplitude) * (1 - churn_rate)`
/// (validation keeps both factors positive) the expected attempt count is
/// a small constant; the cap exists so a hostile config fails with a
/// typed error rather than spinning.
pub const MAX_ATTEMPTS_PER_SLOT: u64 = 64;

/// One rung of the device-class ladder.
#[derive(Clone, Copy, Debug)]
pub struct DeviceClass {
    /// canonical name (stable: used in summaries and docs)
    pub name: &'static str,
    /// population share (the four shares sum to exactly 1.0)
    pub share: f64,
    /// scales the straggler latency draw (flagships finish faster)
    pub latency_mult: f64,
    /// scales the cohort dropout probability
    pub dropout_mult: f64,
    /// scales the chaos fault/crash probabilities
    pub fault_mult: f64,
    /// diurnal phase offset in wave periods (classes peak at different
    /// times of day)
    pub wave_phase: f64,
}

/// The fixed four-rung ladder. Constant by design: per-class knobs in the
/// config would explode the canonical fingerprint, and the scenario axis
/// we care about (how *much* heterogeneity) is already spanned by
/// `wave_amplitude` / `churn_rate` / the cohort and chaos tables.
pub const DEVICE_CLASSES: [DeviceClass; 4] = [
    DeviceClass {
        name: "flagship",
        share: 0.15,
        latency_mult: 0.6,
        dropout_mult: 0.5,
        fault_mult: 0.5,
        wave_phase: 0.0,
    },
    DeviceClass {
        name: "mid",
        share: 0.35,
        latency_mult: 1.0,
        dropout_mult: 1.0,
        fault_mult: 1.0,
        wave_phase: 0.25,
    },
    DeviceClass {
        name: "budget",
        share: 0.35,
        latency_mult: 1.6,
        dropout_mult: 1.5,
        fault_mult: 1.5,
        wave_phase: 0.5,
    },
    DeviceClass {
        name: "iot",
        share: 0.15,
        latency_mult: 2.5,
        dropout_mult: 2.0,
        fault_mult: 2.0,
        wave_phase: 0.75,
    },
];

/// Number of device classes (array lengths in stats/summaries).
pub const NUM_CLASSES: usize = DEVICE_CLASSES.len();

/// `[population]` table — the whole scenario fits in a `Copy` struct.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PopulationConfig {
    /// master switch; when false every other knob must stay at default
    pub enabled: bool,
    /// registered fleet size (10^6–10^7 is the design target)
    pub registered: usize,
    /// edge aggregators between clients and the root (1 = flat)
    pub edges: usize,
    /// fraction of clients that duty-cycle (join/leave churners)
    pub churn_rate: f64,
    /// rounds per churn epoch
    pub churn_period: u64,
    /// diurnal dip depth in `[0, 1)` (0 = always fully available)
    pub wave_amplitude: f64,
    /// rounds per diurnal cycle
    pub wave_period: u64,
}

impl Default for PopulationConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            registered: 1_000_000,
            edges: 1,
            churn_rate: 0.0,
            churn_period: 16,
            wave_amplitude: 0.0,
            wave_period: 24,
        }
    }
}

impl PopulationConfig {
    /// The disabled default (classic materialized population).
    pub fn off() -> Self {
        Self::default()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if !self.enabled {
            return Ok(());
        }
        anyhow::ensure!(
            self.registered > 0,
            "population.registered must be > 0"
        );
        anyhow::ensure!(self.edges >= 1, "population.edges must be >= 1");
        anyhow::ensure!(
            (0.0..1.0).contains(&self.churn_rate),
            "population.churn_rate must be in [0, 1): a full-churn fleet \
             has rounds where nobody is registered"
        );
        anyhow::ensure!(
            self.churn_period >= 1,
            "population.churn_period must be >= 1 round"
        );
        anyhow::ensure!(
            (0.0..1.0).contains(&self.wave_amplitude),
            "population.wave_amplitude must be in [0, 1): a full dip \
             leaves troughs with zero availability"
        );
        anyhow::ensure!(
            self.wave_period >= 1,
            "population.wave_period must be >= 1 round"
        );
        Ok(())
    }
}

/// Lazily derived per-client facts — everything downstream of `(seed,
/// cid)`, nothing stored.
#[derive(Clone, Copy, Debug)]
pub struct ClientProfile {
    pub cid: usize,
    /// index into [`DEVICE_CLASSES`]
    pub class: usize,
    /// whether this client duty-cycles (decided by `churn_rate`)
    pub churner: bool,
    /// phase offset in `[0, CHURN_CYCLE)` epochs
    pub churn_phase: u64,
}

#[inline]
fn profile_rng(seed: u64, cid: usize) -> Xoshiro256pp {
    Xoshiro256pp::new(hash_seed(&[seed, PROFILE_STREAM, cid as u64]))
}

#[inline]
fn pick_class(u: f64) -> usize {
    let mut acc = 0.0;
    for (i, c) in DEVICE_CLASSES.iter().enumerate() {
        acc += c.share;
        if u < acc {
            return i;
        }
    }
    NUM_CLASSES - 1
}

/// Device class of `cid` — the first draw of the profile stream, so it
/// agrees with [`derive_profile`] by construction.
#[inline]
pub fn class_of(seed: u64, cid: usize) -> usize {
    pick_class(profile_rng(seed, cid).next_f64())
}

/// Full lazy profile. Draw order is fixed (class, churn variate, churn
/// phase) — extend only by appending draws, or every existing golden
/// moves.
pub fn derive_profile(
    cfg: &PopulationConfig,
    seed: u64,
    cid: usize,
) -> ClientProfile {
    let mut rng = profile_rng(seed, cid);
    let class = pick_class(rng.next_f64());
    let u_churn = rng.next_f64();
    let churn_phase = rng.next_below(CHURN_CYCLE);
    ClientProfile {
        cid,
        class,
        churner: u_churn < cfg.churn_rate,
        churn_phase,
    }
}

/// Whether a churner with `phase` is registered during `round`.
#[inline]
fn churn_active(cfg: &PopulationConfig, round: u64, phase: u64) -> bool {
    let epoch = round / cfg.churn_period;
    (epoch + phase) % CHURN_CYCLE < CHURN_DUTY
}

/// Diurnal availability of device class `class` at `round`: a triangle
/// wave dipping by `wave_amplitude` once per `wave_period` rounds,
/// phase-shifted per class. Exact rational arithmetic — no transcendental
/// whose final bit could differ across libm builds.
#[inline]
pub fn wave_availability(
    cfg: &PopulationConfig,
    round: u64,
    class: usize,
) -> f64 {
    if cfg.wave_amplitude <= 0.0 {
        return 1.0;
    }
    let x = round as f64 / cfg.wave_period as f64
        + DEVICE_CLASSES[class].wave_phase;
    let frac = x - x.floor();
    let tri = 1.0 - 2.0 * (frac - 0.5).abs();
    1.0 - cfg.wave_amplitude * tri
}

#[inline]
fn unit_from_hash(h: u64) -> f64 {
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Why a candidate was unavailable this round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Availability {
    Active,
    /// churner in a leave epoch
    Churned,
    /// rejected by the diurnal wave gate
    Waved,
}

/// Availability of `cid` at `round` — pure in `(cfg, seed, round, cid)`,
/// independent of sampling order (the wave gate hashes its own stream
/// rather than consuming the sampler's RNG).
pub fn availability(
    cfg: &PopulationConfig,
    seed: u64,
    round: u64,
    cid: usize,
) -> Availability {
    let p = derive_profile(cfg, seed, cid);
    if p.churner && !churn_active(cfg, round, p.churn_phase) {
        return Availability::Churned;
    }
    let a = wave_availability(cfg, round, p.class);
    if a < 1.0 {
        let u = unit_from_hash(
            SplitMix64::new(hash_seed(&[seed, WAVE_STREAM, round, cid as u64]))
                .next_u64(),
        );
        if u >= a {
            return Availability::Waved;
        }
    }
    Availability::Active
}

/// Analytic expected active count at `round` — O(classes), no sampling.
/// Churner phases are uniform, so the churn factor is the constant
/// `1 - churn_rate * (1 - CHURN_DUTY/CHURN_CYCLE)`; the wave factor is
/// the share-weighted per-class availability.
pub fn active_estimate(cfg: &PopulationConfig, round: u64) -> f64 {
    let churn_frac = 1.0
        - cfg.churn_rate * (1.0 - CHURN_DUTY as f64 / CHURN_CYCLE as f64);
    let wave: f64 = DEVICE_CLASSES
        .iter()
        .enumerate()
        .map(|(i, c)| c.share * wave_availability(cfg, round, i))
        .sum();
    cfg.registered as f64 * churn_frac * wave
}

/// Rejection-sampling tallies for one round — the scenario counters the
/// sweep summary surfaces (schema v5).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SampleStats {
    /// candidate draws (accepts + all rejections)
    pub attempts: u64,
    /// candidate already in the cohort
    pub duplicate_rejections: u64,
    /// candidate churned out this epoch
    pub churn_rejections: u64,
    /// candidate gated by the diurnal wave
    pub wave_rejections: u64,
    /// analytic expected active count this round
    pub active_estimate: f64,
    /// accepted clients per device class
    pub class_sampled: [u64; NUM_CLASSES],
}

/// Draw a `k`-client cohort from the registered fleet at `round` without
/// enumerating it: candidates come uniformly from `0..registered`, and
/// unavailable or duplicate draws are rejected. Deterministic in
/// `(cfg, seed, round, k)`; memory and time are O(k), independent of
/// `registered`. The returned ids are sorted ascending (same contract as
/// the uniform sampler).
pub fn sample_cohort(
    cfg: &PopulationConfig,
    seed: u64,
    round: u64,
    k: usize,
) -> Result<(Vec<usize>, SampleStats), SamplerError> {
    let mut stats = SampleStats {
        active_estimate: active_estimate(cfg, round),
        ..SampleStats::default()
    };
    if k == 0 {
        return Ok((Vec::new(), stats));
    }
    let want = k.min(cfg.registered);
    let cap = MAX_ATTEMPTS_PER_SLOT
        .saturating_mul(want as u64)
        .saturating_add(256);
    let mut rng =
        Xoshiro256pp::new(hash_seed(&[seed, SAMPLE_STREAM, round]));
    let mut chosen: Vec<usize> = Vec::with_capacity(want);
    let mut member = std::collections::HashSet::with_capacity(want * 2);
    while chosen.len() < want {
        if stats.attempts >= cap {
            return Err(SamplerError::AvailabilityExhausted {
                round,
                wanted: want,
                got: chosen.len(),
                attempts: stats.attempts,
            });
        }
        stats.attempts += 1;
        let cid = rng.next_below(cfg.registered as u64) as usize;
        if member.contains(&cid) {
            stats.duplicate_rejections += 1;
            continue;
        }
        match availability(cfg, seed, round, cid) {
            Availability::Churned => stats.churn_rejections += 1,
            Availability::Waved => stats.wave_rejections += 1,
            Availability::Active => {
                member.insert(cid);
                chosen.push(cid);
                stats.class_sampled[class_of(seed, cid)] += 1;
            }
        }
    }
    chosen.sort_unstable();
    Ok((chosen, stats))
}

/// Edge→root transport tallies for one round.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EdgeStats {
    /// merged frames the edges uplinked to the root
    pub frames: u64,
    /// shipped bytes on the edge→root hop (headers included)
    pub up_bytes: u64,
    /// bytes the XOR-delta stage saved vs verbatim edge frames
    pub delta_saved: u64,
}

/// Everything the sweep summary records about one population-mode round
/// (schema v5): the scenario counters plus the edge-hop transport.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PopulationRoundStats {
    /// registered fleet size
    pub registered: usize,
    /// configured edge aggregators
    pub edges: usize,
    /// rejection-sampling tallies for this round's cohort
    pub sample: SampleStats,
    /// clients whose planned fate was `Completes`, per device class
    pub class_completed: [u64; NUM_CLASSES],
    /// edge→root transport tallies
    pub edge: EdgeStats,
}

/// Edge→root frame nonce — keyed like every other nonce stream.
#[inline]
pub fn edge_nonce(seed: u64, round: u64, edge: usize) -> u64 {
    hash_seed(&[seed, EDGE_NONCE_STREAM, round, edge as u64])
}

/// Shipped-frame tag: verbatim wire frame follows.
const EDGE_TAG_VERBATIM: u8 = 0;
/// Shipped-frame tag: XOR-delta stream vs the previous round's verbatim
/// payload follows.
const EDGE_TAG_DELTA: u8 = 1;
/// `tag(1) + weight(f64) + clients(u64)` — participation travels beside
/// the frame, not inside it, so the frame body stays pure sums and the
/// single-edge bit-exactness argument stays one line.
const EDGE_HEADER_LEN: usize = 1 + 8 + 8;

/// One encoded edge→root uplink plus its accounting.
#[derive(Clone, Debug)]
pub struct EdgeFrame {
    /// header + (verbatim | delta) payload, ready for the wire
    pub shipped: Vec<u8>,
    /// the verbatim wire frame — the delta base for next round
    pub verbatim: Vec<u8>,
    /// bytes the delta stage saved vs shipping verbatim (0 on fallback)
    pub delta_saved: u64,
}

/// Encode an edge aggregator's state for the root: the weighted f64 sums
/// cast to f32 and written as raw wire variables (v2 integrity framing
/// when `integrity`), with the edge's normalized weight and client count
/// in a fixed header. When `prev` holds last round's verbatim payload of
/// identical length, the frame is XOR-delta coded against it and the
/// smaller encoding ships — the same pure-function fallback rule the
/// client uplink uses (`docs/WIRE.md`).
pub fn encode_edge_frame(
    agg: &StreamingAggregator,
    integrity: bool,
    nonce: u64,
    delta: bool,
    prev: &[u8],
) -> EdgeFrame {
    let sums = agg.cast_sums();
    let payload_guess: usize =
        sums.iter().map(|v| v.len() * 4 + 16).sum::<usize>() + 32;
    let mut w = if integrity {
        WireWriter::with_integrity(payload_guess, nonce)
    } else {
        WireWriter::with_capacity(payload_guess)
    };
    for var in &sums {
        w.raw(var);
    }
    let verbatim = w.finish();

    let mut shipped = Vec::with_capacity(EDGE_HEADER_LEN + verbatim.len());
    shipped.push(EDGE_TAG_VERBATIM);
    shipped.extend_from_slice(&agg.total_weight().to_le_bytes());
    shipped.extend_from_slice(&(agg.clients() as u64).to_le_bytes());

    let mut delta_saved = 0u64;
    if delta && prev.len() == verbatim.len() && !prev.is_empty() {
        let mut xored = Vec::new();
        let mut stream = Vec::new();
        xor_encode_into(&verbatim, prev, &mut xored, &mut stream);
        if stream.len() < verbatim.len() {
            shipped[0] = EDGE_TAG_DELTA;
            delta_saved = (verbatim.len() - stream.len()) as u64;
            shipped.extend_from_slice(&stream);
            return EdgeFrame {
                shipped,
                verbatim,
                delta_saved,
            };
        }
    }
    shipped.extend_from_slice(&verbatim);
    EdgeFrame {
        shipped,
        verbatim,
        delta_saved,
    }
}

/// Decode one shipped edge frame at the root and fold it into `root`.
/// Verifies the frame (header/record CRCs when integrity framing is on,
/// duplicate-nonce replay via `ledger`) and returns the verbatim payload
/// so the caller can retain it as next round's delta base.
pub fn decode_edge_frame(
    shipped: &[u8],
    prev: &[u8],
    root: &mut StreamingAggregator,
    ledger: &mut codec::NonceLedger,
    expect_nonce: Option<u64>,
) -> anyhow::Result<Vec<u8>> {
    anyhow::ensure!(
        shipped.len() >= EDGE_HEADER_LEN,
        "edge frame shorter than its header: {} bytes",
        shipped.len()
    );
    let tag = shipped[0];
    let weight = f64::from_le_bytes(shipped[1..9].try_into().unwrap());
    let clients = u64::from_le_bytes(shipped[9..17].try_into().unwrap());
    let body = &shipped[EDGE_HEADER_LEN..];
    let verbatim: Vec<u8> = match tag {
        EDGE_TAG_VERBATIM => body.to_vec(),
        EDGE_TAG_DELTA => {
            let mut scratch = Vec::new();
            let mut out = Vec::new();
            xor_decode_into(body, prev, &mut scratch, &mut out)
                .map_err(|e| anyhow::anyhow!("edge delta decode: {e:?}"))?;
            out
        }
        other => anyhow::bail!("unknown edge frame tag {other}"),
    };
    let info = codec::verify_frame(&verbatim)
        .map_err(|e| anyhow::anyhow!("edge frame rejected: {e:?}"))?;
    if let Some(want) = expect_nonce {
        anyhow::ensure!(
            info.nonce == Some(want),
            "edge frame nonce mismatch: got {:?}, want {want}",
            info.nonce
        );
    }
    ledger
        .observe(info.nonce)
        .map_err(|e| anyhow::anyhow!("edge frame replay: {e:?}"))?;
    let mut vi = 0usize;
    codec::for_each_var(&verbatim, |i, view| {
        let codec::VarView::Raw { data, n } = view else {
            anyhow::bail!("edge frame var {i} is not raw f32 sums");
        };
        root.absorb_cast_var(i, data, n)?;
        vi += 1;
        Ok(())
    })
    .map_err(|e| anyhow::anyhow!("edge frame decode: {e:?}"))?;
    anyhow::ensure!(
        vi == root.num_vars(),
        "edge frame carried {vi} vars, root expects {}",
        root.num_vars()
    );
    root.absorb_participation(weight, clients as usize);
    Ok(verbatim)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PopulationConfig {
        PopulationConfig {
            enabled: true,
            registered: 1_000_000,
            edges: 4,
            churn_rate: 0.3,
            churn_period: 2,
            wave_amplitude: 0.5,
            wave_period: 6,
        }
    }

    #[test]
    fn validate_accepts_defaults_and_rejects_bad_knobs() {
        PopulationConfig::off().validate().unwrap();
        cfg().validate().unwrap();
        let mut c = cfg();
        c.churn_rate = 1.0;
        assert!(c.validate().is_err());
        c = cfg();
        c.wave_amplitude = 1.0;
        assert!(c.validate().is_err());
        c = cfg();
        c.edges = 0;
        assert!(c.validate().is_err());
        c = cfg();
        c.registered = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn class_shares_sum_to_one() {
        let total: f64 = DEVICE_CLASSES.iter().map(|c| c.share).sum();
        assert!((total - 1.0).abs() < 1e-12, "shares sum to {total}");
    }

    #[test]
    fn class_of_matches_profile_and_roughly_matches_shares() {
        let c = cfg();
        let mut counts = [0u64; NUM_CLASSES];
        for cid in 0..20_000usize {
            let k = class_of(42, cid);
            assert_eq!(k, derive_profile(&c, 42, cid).class);
            counts[k] += 1;
        }
        for (i, dc) in DEVICE_CLASSES.iter().enumerate() {
            let frac = counts[i] as f64 / 20_000.0;
            assert!(
                (frac - dc.share).abs() < 0.02,
                "{}: {frac} vs {}",
                dc.name,
                dc.share
            );
        }
    }

    #[test]
    fn availability_is_pure_and_seed_sensitive() {
        let c = cfg();
        for cid in [0usize, 17, 999_999] {
            for round in 0..12 {
                assert_eq!(
                    availability(&c, 7, round, cid),
                    availability(&c, 7, round, cid)
                );
            }
        }
        // different seeds must disagree for at least some (round, cid)
        let mut diff = false;
        for cid in 0..200usize {
            diff |= availability(&c, 1, 3, cid) != availability(&c, 2, 3, cid);
        }
        assert!(diff);
    }

    #[test]
    fn churners_duty_cycle_and_residents_never_churn() {
        let c = cfg();
        let seed = 5u64;
        // find one churner and one resident
        let mut churner = None;
        let mut resident = None;
        for cid in 0..1000usize {
            let p = derive_profile(&c, seed, cid);
            if p.churner {
                churner.get_or_insert(cid);
            } else {
                resident.get_or_insert(cid);
            }
        }
        let (ch, re) = (churner.unwrap(), resident.unwrap());
        let mut ever_churned = false;
        let mut ever_active = false;
        for round in 0..(CHURN_CYCLE * c.churn_period * 2) {
            match availability(&c, seed, round, ch) {
                Availability::Churned => ever_churned = true,
                _ => ever_active = true,
            }
            assert_ne!(
                availability(&c, seed, round, re),
                Availability::Churned,
                "resident churned at round {round}"
            );
        }
        assert!(ever_churned && ever_active, "duty cycle must alternate");
    }

    #[test]
    fn wave_is_triangle_between_amplitude_bounds() {
        let c = cfg();
        for class in 0..NUM_CLASSES {
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for round in 0..(c.wave_period * 3) {
                let a = wave_availability(&c, round, class);
                assert!((0.0..=1.0).contains(&a));
                lo = lo.min(a);
                hi = hi.max(a);
            }
            assert!(hi > 1.0 - c.wave_amplitude * 0.5, "class {class} flat");
            assert!(lo < 1.0 - c.wave_amplitude * 0.5, "class {class} flat");
        }
        // amplitude 0 short-circuits to full availability
        let mut flat = c;
        flat.wave_amplitude = 0.0;
        assert_eq!(wave_availability(&flat, 3, 0), 1.0);
    }

    #[test]
    fn sample_cohort_is_deterministic_sorted_distinct_and_counted() {
        let c = cfg();
        let (ids, stats) = sample_cohort(&c, 42, 3, 64).unwrap();
        let (ids2, stats2) = sample_cohort(&c, 42, 3, 64).unwrap();
        assert_eq!(ids, ids2);
        assert_eq!(stats, stats2);
        assert_eq!(ids.len(), 64);
        let mut d = ids.clone();
        d.dedup();
        assert_eq!(d.len(), 64, "ids must be distinct");
        assert!(ids.windows(2).all(|w| w[0] < w[1]), "sorted ascending");
        assert!(ids.iter().all(|&i| i < c.registered));
        assert_eq!(
            stats.class_sampled.iter().sum::<u64>(),
            64,
            "every accept is classed"
        );
        assert!(stats.attempts >= 64);
        assert_eq!(
            stats.attempts,
            64 + stats.duplicate_rejections
                + stats.churn_rejections
                + stats.wave_rejections
        );
        // the scenario knobs are on, so rejections must actually occur
        assert!(stats.churn_rejections + stats.wave_rejections > 0);
    }

    #[test]
    fn sample_cohort_only_returns_active_clients() {
        let c = cfg();
        let (ids, _) = sample_cohort(&c, 9, 5, 32).unwrap();
        for cid in ids {
            assert_eq!(availability(&c, 9, 5, cid), Availability::Active);
        }
    }

    #[test]
    fn sample_cohort_clamps_to_registered_and_handles_k_zero() {
        let mut c = cfg();
        c.registered = 8;
        c.churn_rate = 0.0;
        c.wave_amplitude = 0.0;
        let (ids, _) = sample_cohort(&c, 1, 0, 100).unwrap();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
        let (empty, stats) = sample_cohort(&c, 1, 0, 0).unwrap();
        assert!(empty.is_empty());
        assert_eq!(stats.attempts, 0);
    }

    #[test]
    fn sample_cohort_exhaustion_is_a_typed_error() {
        // tiny population with most of it churned away and a deep wave:
        // asking for the whole fleet must fail with the typed error, not
        // hang or panic
        let c = PopulationConfig {
            enabled: true,
            registered: 4,
            edges: 1,
            churn_rate: 0.99,
            churn_period: 1,
            wave_amplitude: 0.99,
            wave_period: 2,
        };
        let mut saw_exhausted = false;
        for round in 0..8 {
            if let Err(SamplerError::AvailabilityExhausted {
                wanted, ..
            }) = sample_cohort(&c, 3, round, 4)
            {
                assert_eq!(wanted, 4);
                saw_exhausted = true;
            }
        }
        assert!(saw_exhausted, "blackout config must exhaust at least once");
    }

    #[test]
    fn active_estimate_tracks_empirical_availability() {
        let c = cfg();
        let seed = 11u64;
        for round in [0u64, 3, 7] {
            let est = active_estimate(&c, round) / c.registered as f64;
            let mut active = 0usize;
            let n = 20_000usize;
            for cid in 0..n {
                if availability(&c, seed, round, cid) == Availability::Active
                {
                    active += 1;
                }
            }
            let emp = active as f64 / n as f64;
            assert!(
                (emp - est).abs() < 0.02,
                "round {round}: empirical {emp} vs estimate {est}"
            );
        }
    }

    #[test]
    fn edge_frame_round_trips_with_and_without_integrity() {
        let var_lens = vec![33usize, 7];
        for integrity in [false, true] {
            let mut edge = StreamingAggregator::new(&var_lens);
            let vals: Vec<Vec<f32>> = var_lens
                .iter()
                .map(|&n| (0..n).map(|i| i as f32 * 0.25 - 3.0).collect())
                .collect();
            for (i, v) in vals.iter().enumerate() {
                edge.absorb_cast_var(i, bytemuckish(v), v.len()).unwrap();
            }
            edge.absorb_participation(0.5, 3);
            let nonce = edge_nonce(7, 2, 0);
            let f = encode_edge_frame(&edge, integrity, nonce, false, &[]);
            assert_eq!(f.delta_saved, 0);
            let mut root = StreamingAggregator::new(&var_lens);
            let mut ledger = codec::NonceLedger::new(8);
            let want = if integrity { Some(nonce) } else { None };
            let verbatim = decode_edge_frame(
                &f.shipped,
                &[],
                &mut root,
                &mut ledger,
                want,
            )
            .unwrap();
            assert_eq!(verbatim, f.verbatim);
            assert_eq!(root.clients(), 3);
            assert!((root.total_weight() - 0.5).abs() < 1e-12);
            assert_eq!(root.cast_sums(), vals);
            if integrity {
                // replaying the same nonce must be refused
                let mut root2 = StreamingAggregator::new(&var_lens);
                assert!(decode_edge_frame(
                    &f.shipped,
                    &[],
                    &mut root2,
                    &mut ledger,
                    want
                )
                .is_err());
            }
        }
    }

    #[test]
    fn edge_frame_delta_saves_bytes_and_decodes_exactly() {
        let var_lens = vec![256usize];
        let mk = |bias: f32| {
            let mut a = StreamingAggregator::new(&var_lens);
            let v: Vec<f32> = (0..256).map(|i| i as f32 + bias).collect();
            a.absorb_cast_var(0, bytemuckish(&v), v.len()).unwrap();
            a.absorb_participation(1.0, 4);
            a
        };
        let prev_frame =
            encode_edge_frame(&mk(0.0), true, edge_nonce(1, 0, 0), false, &[]);
        // next round: nearly identical sums → the XOR stream collapses
        let cur = encode_edge_frame(
            &mk(0.0),
            true,
            edge_nonce(1, 1, 0),
            true,
            &prev_frame.verbatim,
        );
        assert!(cur.delta_saved > 0, "identical payloads must delta-win");
        assert!(cur.shipped.len() < cur.verbatim.len() + EDGE_HEADER_LEN);
        let mut root = StreamingAggregator::new(&var_lens);
        let mut ledger = codec::NonceLedger::new(8);
        let verbatim = decode_edge_frame(
            &cur.shipped,
            &prev_frame.verbatim,
            &mut root,
            &mut ledger,
            Some(edge_nonce(1, 1, 0)),
        )
        .unwrap();
        assert_eq!(verbatim, cur.verbatim, "delta decode must be lossless");
    }

    #[test]
    fn corrupted_edge_frame_is_rejected() {
        let var_lens = vec![64usize];
        let mut edge = StreamingAggregator::new(&var_lens);
        let v: Vec<f32> = (0..64).map(|i| i as f32).collect();
        edge.absorb_cast_var(0, bytemuckish(&v), v.len()).unwrap();
        edge.absorb_participation(1.0, 2);
        let f = encode_edge_frame(&edge, true, edge_nonce(3, 0, 1), false, &[]);
        let mut bad = f.shipped.clone();
        let mid = EDGE_HEADER_LEN + bad.len() / 2;
        bad[mid] ^= 0x40;
        let mut root = StreamingAggregator::new(&var_lens);
        let mut ledger = codec::NonceLedger::new(8);
        assert!(decode_edge_frame(
            &bad,
            &[],
            &mut root,
            &mut ledger,
            Some(edge_nonce(3, 0, 1))
        )
        .is_err());
        assert_eq!(root.clients(), 0, "rejected frame must not fold");
    }

    /// f32 slice → little-endian bytes (tests only; the wire writer does
    /// this for real frames).
    fn bytemuckish(v: &[f32]) -> &'static [u8] {
        let mut out = Vec::with_capacity(v.len() * 4);
        for x in v {
            out.extend_from_slice(&x.to_le_bytes());
        }
        Box::leak(out.into_boxed_slice())
    }
}
