//! Server state, FedAvg aggregation, and the streaming aggregation path.
//!
//! The server keeps the global model in full precision (OMC targets
//! *client* memory and the *transport*; the paper's server receives
//! decompressed updates and aggregates them). Aggregation is weighted
//! FedAvg over client models, with optional server momentum (FedAvgM) —
//! off by default, matching the paper's setup of plain averaging.
//!
//! # Two aggregation paths (§Scale)
//!
//! * [`Server::aggregate`] is the **reference** implementation: it takes
//!   every client model fully materialized (`&[Vec<Vec<f32>>]`,
//!   O(cohort × params) f32s) and folds the weighted mean in f64. Simple,
//!   obviously correct, kept as the comparison baseline.
//! * [`StreamingAggregator`] is the **production** path the round engine
//!   uses: each client's uplink wire frame is decoded one variable at a
//!   time into a reused scratch buffer and folded into per-variable f64
//!   sums, then the frame is dropped. Server working memory is
//!   O(params) per accumulator — independent of cohort size. Accumulating
//!   clients in the same order with the same normalized weights performs
//!   the identical f64 operations as the reference, so the two paths are
//!   bit-exact (asserted by tests); merging per-shard accumulators only
//!   reassociates the f64 sums (differences ≤ 1e-6 per element).
//!
//! Both paths share [`Server::apply_mean`] for the momentum / write-back
//! tail, so they cannot diverge there.

use anyhow::Result;

use crate::omc::codec::{self, VarView};
use crate::omc::delta::DeltaBase;
use crate::omc::pack;

/// The server's global model + optimizer state.
#[derive(Clone, Debug)]
pub struct Server {
    /// full-precision master copy, one Vec per manifest variable
    pub params: Vec<Vec<f32>>,
    /// momentum buffers (allocated lazily when momentum > 0)
    velocity: Option<Vec<Vec<f32>>>,
    /// FedAvgM momentum coefficient in `[0, 1)`; 0 = plain FedAvg
    pub momentum: f32,
    /// rounds aggregated (or skipped) so far
    pub round: usize,
}

impl Server {
    /// Wrap initial global parameters (one `Vec<f32>` per variable).
    pub fn new(params: Vec<Vec<f32>>) -> Self {
        Self {
            params,
            velocity: None,
            momentum: 0.0,
            round: 0,
        }
    }

    /// Enable FedAvgM server momentum.
    pub fn with_momentum(mut self, m: f32) -> Self {
        assert!((0.0..1.0).contains(&m), "momentum in [0,1)");
        self.momentum = m;
        self
    }

    /// Total scalar parameter count across variables.
    pub fn num_params(&self) -> usize {
        self.params.iter().map(|v| v.len()).sum()
    }

    /// Per-variable element counts (the shape a [`StreamingAggregator`]
    /// must match).
    pub fn var_lens(&self) -> Vec<usize> {
        self.params.iter().map(|v| v.len()).collect()
    }

    /// Advance the round counter without touching the global model — used
    /// when an entire cohort dropped out or missed the deadline and there
    /// is nothing to aggregate.
    pub fn skip_round(&mut self) {
        self.round += 1;
    }

    /// Reference FedAvg: replace the global model with the weighted mean of
    /// fully-materialized client models. `weights` default to uniform; with
    /// momentum > 0 the weighted mean *delta* is applied through a velocity
    /// buffer instead. The streaming path ([`StreamingAggregator`]) must
    /// match this bit-for-bit when fed the same clients in the same order.
    pub fn aggregate(
        &mut self,
        client_models: &[Vec<Vec<f32>>],
        weights: Option<&[f64]>,
    ) -> Result<()> {
        anyhow::ensure!(!client_models.is_empty(), "no client models to aggregate");
        let uniform = vec![1.0; client_models.len()];
        let w = weights.unwrap_or(&uniform);
        anyhow::ensure!(
            w.len() == client_models.len(),
            "weights/models length mismatch"
        );
        let total: f64 = w.iter().sum();
        anyhow::ensure!(total > 0.0, "non-positive total weight");
        for m in client_models {
            anyhow::ensure!(
                m.len() == self.params.len(),
                "client model has {} vars, server has {}",
                m.len(),
                self.params.len()
            );
        }

        // weighted mean, accumulated in f64 for determinism across client
        // counts
        let mut mean: Vec<Vec<f64>> = self
            .params
            .iter()
            .map(|v| vec![0.0f64; v.len()])
            .collect();
        for (ci, m) in client_models.iter().enumerate() {
            let wc = w[ci] / total;
            for (vi, var) in m.iter().enumerate() {
                anyhow::ensure!(
                    var.len() == self.params[vi].len(),
                    "variable {vi} length mismatch"
                );
                let acc = &mut mean[vi];
                for (a, &x) in acc.iter_mut().zip(var) {
                    *a += wc * x as f64;
                }
            }
        }
        self.apply_mean(mean);
        Ok(())
    }

    /// Write a computed f64 weighted mean into the global model (through
    /// the momentum buffer when enabled) and advance the round counter.
    /// Shared tail of the reference and streaming aggregation paths; the
    /// caller guarantees `mean` matches the parameter shapes.
    pub fn apply_mean(&mut self, mean: Vec<Vec<f64>>) {
        debug_assert_eq!(mean.len(), self.params.len());
        if self.momentum > 0.0 {
            let mom = self.momentum as f64;
            let vel = self.velocity.get_or_insert_with(|| {
                self.params.iter().map(|v| vec![0.0f32; v.len()]).collect()
            });
            for (vi, var) in self.params.iter_mut().enumerate() {
                for (ei, p) in var.iter_mut().enumerate() {
                    let delta = mean[vi][ei] - *p as f64;
                    let v = mom * vel[vi][ei] as f64 + delta;
                    vel[vi][ei] = v as f32;
                    *p = (*p as f64 + v) as f32;
                }
            }
        } else {
            for (vi, var) in self.params.iter_mut().enumerate() {
                for (ei, p) in var.iter_mut().enumerate() {
                    *p = mean[vi][ei] as f32;
                }
            }
        }
        self.round += 1;
    }
}

/// Streaming weighted-FedAvg accumulator (see the module docs).
///
/// Feed it client updates one at a time — as decoded models
/// ([`accumulate_model`](Self::accumulate_model)) or directly as uplink
/// wire frames ([`accumulate_wire`](Self::accumulate_wire), which decodes
/// each variable into a caller-owned scratch buffer and never materializes
/// a whole client model). Weights must be pre-normalized (sum to 1 over
/// everything accumulated into the final aggregator) so the accumulation
/// performs exactly the reference implementation's f64 operations.
///
/// Shard-parallel use: give each worker its own accumulator, then
/// [`merge`](Self::merge) them in a fixed order and
/// [`apply`](Self::apply) once.
#[derive(Clone, Debug)]
pub struct StreamingAggregator {
    /// per-variable f64 weighted sums
    sums: Vec<Vec<f64>>,
    /// total normalized weight accumulated (must end at ~1.0)
    weight: f64,
    /// number of client updates folded in
    clients: usize,
}

impl StreamingAggregator {
    /// Empty accumulator for variables of the given element counts.
    pub fn new(var_lens: &[usize]) -> Self {
        Self {
            sums: var_lens.iter().map(|&n| vec![0.0f64; n]).collect(),
            weight: 0.0,
            clients: 0,
        }
    }

    /// Empty accumulator shaped like the server's global model.
    pub fn for_server(server: &Server) -> Self {
        Self::new(&server.var_lens())
    }

    /// Client updates folded in so far.
    pub fn clients(&self) -> usize {
        self.clients
    }

    /// Sum of the normalized weights folded in so far.
    pub fn total_weight(&self) -> f64 {
        self.weight
    }

    /// Accounted working memory of this accumulator in bytes (the f64
    /// sums). O(params), independent of how many clients were folded in —
    /// the quantity the cohort-scaling tests assert.
    pub fn memory_bytes(&self) -> usize {
        self.sums.iter().map(|v| v.len() * 8).sum()
    }

    /// Number of variables this accumulator covers.
    pub fn num_vars(&self) -> usize {
        self.sums.len()
    }

    /// The weighted f64 sums cast to f32 — the payload of an edge→root
    /// frame (`fl::population`). Shipping *sums* rather than means keeps
    /// the single-edge topology bit-exact against flat aggregation: the
    /// root re-widens each f32 to f64 losslessly and
    /// [`apply`](Self::apply) casts the total back to the identical f32.
    pub fn cast_sums(&self) -> Vec<Vec<f32>> {
        self.sums
            .iter()
            .map(|v| v.iter().map(|&x| x as f32).collect())
            .collect()
    }

    /// Fold one decoded edge-frame variable (little-endian f32 sums of
    /// `n` elements) in by pure addition — the streaming twin of
    /// [`merge`](Self::merge). Participation arrives separately via
    /// [`absorb_participation`](Self::absorb_participation).
    pub fn absorb_cast_var(
        &mut self,
        var: usize,
        data: &[u8],
        n: usize,
    ) -> Result<()> {
        anyhow::ensure!(
            var < self.sums.len(),
            "edge var {var} out of range ({} vars)",
            self.sums.len()
        );
        anyhow::ensure!(
            n == self.sums[var].len() && data.len() == n * 4,
            "edge var {var}: {n} elements / {} bytes, aggregator expects {}",
            data.len(),
            self.sums[var].len()
        );
        for (j, a) in self.sums[var].iter_mut().enumerate() {
            let b: [u8; 4] = data[j * 4..j * 4 + 4].try_into().unwrap();
            *a += f32::from_le_bytes(b) as f64;
        }
        Ok(())
    }

    /// Account an edge's participation: its summed normalized weight and
    /// folded client count (carried beside the frame, not inside it).
    pub fn absorb_participation(&mut self, weight: f64, clients: usize) {
        self.weight += weight;
        self.clients += clients;
    }

    /// Fold one fully-decoded client model in with normalized weight `wc`.
    pub fn accumulate_model(&mut self, model: &[Vec<f32>], wc: f64) -> Result<()> {
        anyhow::ensure!(
            model.len() == self.sums.len(),
            "client model has {} vars, aggregator has {}",
            model.len(),
            self.sums.len()
        );
        for (vi, var) in model.iter().enumerate() {
            anyhow::ensure!(
                var.len() == self.sums[vi].len(),
                "variable {vi} length mismatch"
            );
            for (a, &x) in self.sums[vi].iter_mut().zip(var) {
                *a += wc * x as f64;
            }
        }
        self.weight += wc;
        self.clients += 1;
        Ok(())
    }

    /// Fold one client's uplink wire frame in with normalized weight `wc`.
    ///
    /// Variables are decoded (fused unpack + PVT transform) one at a time
    /// into `scratch`, whose capacity is reused across calls — the frame's
    /// decompressed form never exists in full, so server memory stays
    /// O(params + one variable) no matter the cohort size.
    pub fn accumulate_wire(
        &mut self,
        wire: &[u8],
        wc: f64,
        scratch: &mut Vec<f32>,
    ) -> Result<()> {
        self.accumulate_wire_based(wire, wc, scratch, None)
    }

    /// [`accumulate_wire`](Self::accumulate_wire) with an optional delta
    /// base: v3 uplink frames reconstruct their tag-2 variables against
    /// `base` (the packed downlink payload both sides hold for the
    /// acknowledged version) before the fold. Verbatim v1/v2 frames ignore
    /// the base entirely.
    pub fn accumulate_wire_based(
        &mut self,
        wire: &[u8],
        wc: f64,
        scratch: &mut Vec<f32>,
        base: Option<&DeltaBase<'_>>,
    ) -> Result<()> {
        self.accumulate_wire_with(wire, wc, scratch, base, None)
    }

    /// [`accumulate_wire_based`](Self::accumulate_wire_based) with an
    /// optional sparse base: tag-3 records carry a client's *sparse
    /// update* over the decompressed downlink values both sides hold, so
    /// the fold adds `wc · base[j]` for every coordinate of the variable
    /// and then `wc · value` at the selected indices — the dense client
    /// model is never materialized, only the `k` selected values pass
    /// through `scratch`. `sparse_base[vi]` must hold the decompressed
    /// downlink values of every variable that may arrive sparse (empty
    /// slots are a harness bug, reported as `Err`).
    pub fn accumulate_wire_with(
        &mut self,
        wire: &[u8],
        wc: f64,
        scratch: &mut Vec<f32>,
        base: Option<&DeltaBase<'_>>,
        sparse_base: Option<&[Vec<f32>]>,
    ) -> Result<()> {
        let nvars = self.sums.len();
        let sums = &mut self.sums;
        let decoded = codec::for_each_var_based(wire, base, |vi, view| {
            anyhow::ensure!(vi < nvars, "uplink has more vars than the model");
            if let VarView::Sparse {
                indices,
                payload,
                n,
                fmt,
                pvt,
            } = view
            {
                let sb = sparse_base.ok_or_else(|| {
                    anyhow::anyhow!(
                        "sparse record in var {vi} but no sparse base held"
                    )
                })?;
                let bvar = sb.get(vi).map(Vec::as_slice).unwrap_or(&[]);
                anyhow::ensure!(
                    n == sums[vi].len() && bvar.len() == n,
                    "sparse var {vi} has {n} elements, base {}, expected {}",
                    bvar.len(),
                    sums[vi].len()
                );
                // the base everyone already holds…
                for (a, &x) in sums[vi].iter_mut().zip(bvar) {
                    *a += wc * x as f64;
                }
                // …plus the k selected update values (unpacked into
                // scratch — never a dense n-length buffer)
                pack::unpack_transform_into(
                    payload,
                    indices.len(),
                    fmt,
                    pvt.s,
                    pvt.b,
                    scratch,
                );
                for (&j, &x) in indices.iter().zip(scratch.iter()) {
                    sums[vi][j as usize] += wc * x as f64;
                }
                return Ok(());
            }
            view.decompress_into(&mut *scratch);
            anyhow::ensure!(
                scratch.len() == sums[vi].len(),
                "uplink variable {vi} has {} elements, expected {}",
                scratch.len(),
                sums[vi].len()
            );
            for (a, &x) in sums[vi].iter_mut().zip(scratch.iter()) {
                *a += wc * x as f64;
            }
            Ok(())
        })?;
        anyhow::ensure!(
            decoded == nvars,
            "uplink has {decoded} vars, model expects {nvars}"
        );
        self.weight += wc;
        self.clients += 1;
        Ok(())
    }

    /// Fold one uplink frame in — but only after verifying it end to end.
    ///
    /// [`accumulate_wire`](Self::accumulate_wire) mutates the sums
    /// *progressively*, so a frame that fails mid-decode would leave them
    /// half-updated. This checked variant first walks the frame with
    /// [`codec::verify_frame`] (structure + header/per-variable CRCs, no
    /// decompression) and consults the duplicate-`nonce` ledger; a bad
    /// frame is reported as [`WireVerdict::Rejected`] with the sums
    /// untouched — the round engines *account* it instead of aborting.
    /// `Err` is reserved for shape mismatches against the model, which are
    /// harness bugs, not wire corruption.
    pub fn accumulate_wire_checked(
        &mut self,
        wire: &[u8],
        wc: f64,
        scratch: &mut Vec<f32>,
        ledger: &mut codec::NonceLedger,
    ) -> Result<WireVerdict> {
        self.accumulate_wire_checked_based(wire, wc, scratch, ledger, None)
    }

    /// [`accumulate_wire_checked`](Self::accumulate_wire_checked) with an
    /// optional delta base. Verification is base-free (`verify_frame`
    /// walks structure + CRCs without decoding delta streams), then the
    /// frame's acknowledged base version is checked against the base we
    /// actually hold *before* any fold: a v3 frame whose version we cannot
    /// serve is [`WireVerdict::Rejected`] with the sums untouched — never
    /// a half-applied fold. `Err` still means a harness-level shape bug.
    pub fn accumulate_wire_checked_based(
        &mut self,
        wire: &[u8],
        wc: f64,
        scratch: &mut Vec<f32>,
        ledger: &mut codec::NonceLedger,
        base: Option<&DeltaBase<'_>>,
    ) -> Result<WireVerdict> {
        self.accumulate_wire_checked_with(wire, wc, scratch, ledger, base, None)
    }

    /// [`accumulate_wire_checked_based`](Self::accumulate_wire_checked_based)
    /// with an optional sparse base for tag-3 records (see
    /// [`accumulate_wire_with`](Self::accumulate_wire_with)).
    pub fn accumulate_wire_checked_with(
        &mut self,
        wire: &[u8],
        wc: f64,
        scratch: &mut Vec<f32>,
        ledger: &mut codec::NonceLedger,
        base: Option<&DeltaBase<'_>>,
        sparse_base: Option<&[Vec<f32>]>,
    ) -> Result<WireVerdict> {
        let info = match codec::verify_frame(wire) {
            Ok(info) => info,
            Err(e) => return Ok(WireVerdict::Rejected(e)),
        };
        if let Some(frame_bv) = info.base_version {
            match base {
                None => {
                    return Ok(WireVerdict::Rejected(
                        codec::DecodeError::MissingDeltaBase { var: 0 },
                    ))
                }
                Some(b) if b.version != frame_bv => {
                    return Ok(WireVerdict::Rejected(
                        codec::DecodeError::BaseVersionMismatch {
                            frame: frame_bv,
                            have: b.version,
                        },
                    ))
                }
                Some(_) => {}
            }
        }
        if let Err(e) = ledger.observe(info.nonce) {
            return Ok(WireVerdict::Rejected(e));
        }
        self.accumulate_wire_with(wire, wc, scratch, base, sparse_base)?;
        Ok(WireVerdict::Accepted)
    }

    /// Fold another accumulator (e.g. a shard's) into this one. Merging is
    /// pure f64 addition, so merge order only reassociates the sums.
    pub fn merge(&mut self, other: StreamingAggregator) -> Result<()> {
        anyhow::ensure!(
            other.sums.len() == self.sums.len(),
            "aggregator shape mismatch"
        );
        for (vi, ov) in other.sums.into_iter().enumerate() {
            anyhow::ensure!(
                ov.len() == self.sums[vi].len(),
                "aggregator variable {vi} length mismatch"
            );
            for (a, x) in self.sums[vi].iter_mut().zip(ov) {
                *a += x;
            }
        }
        self.weight += other.weight;
        self.clients += other.clients;
        Ok(())
    }

    /// Finish: write the accumulated weighted mean into the server (through
    /// the shared [`Server::apply_mean`] tail) and advance the round.
    pub fn apply(self, server: &mut Server) -> Result<()> {
        anyhow::ensure!(self.clients > 0, "no client updates to aggregate");
        anyhow::ensure!(
            (self.weight - 1.0).abs() < 1e-6,
            "aggregation weights must be normalized (sum {}, expected 1)",
            self.weight
        );
        anyhow::ensure!(
            self.sums.len() == server.params.len()
                && self
                    .sums
                    .iter()
                    .zip(&server.params)
                    .all(|(s, p)| s.len() == p.len()),
            "aggregator/server shape mismatch"
        );
        server.apply_mean(self.sums);
        Ok(())
    }
}

/// Outcome of [`StreamingAggregator::accumulate_wire_checked`].
#[derive(Debug)]
pub enum WireVerdict {
    /// The frame verified clean and was folded into the sums.
    Accepted,
    /// The frame was rejected before any fold; the sums are untouched.
    Rejected(codec::DecodeError),
}

impl WireVerdict {
    /// True when the frame was folded in.
    pub fn accepted(&self) -> bool {
        matches!(self, WireVerdict::Accepted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::omc::codec::WireWriter;
    use crate::testkit::Gen;

    fn model(vals: &[f32]) -> Vec<Vec<f32>> {
        vec![vals.to_vec()]
    }

    #[test]
    fn uniform_average() {
        let mut s = Server::new(model(&[0.0, 0.0]));
        s.aggregate(&[model(&[1.0, 3.0]), model(&[3.0, 5.0])], None)
            .unwrap();
        assert_eq!(s.params[0], vec![2.0, 4.0]);
        assert_eq!(s.round, 1);
    }

    #[test]
    fn weighted_average() {
        let mut s = Server::new(model(&[0.0]));
        s.aggregate(
            &[model(&[1.0]), model(&[4.0])],
            Some(&[3.0, 1.0]),
        )
        .unwrap();
        assert!((s.params[0][0] - 1.75).abs() < 1e-6);
    }

    #[test]
    fn single_client_replaces() {
        let mut s = Server::new(model(&[9.0, 9.0]));
        s.aggregate(&[model(&[1.0, 2.0])], None).unwrap();
        assert_eq!(s.params[0], vec![1.0, 2.0]);
    }

    #[test]
    fn momentum_accelerates_along_consistent_direction() {
        let mut plain = Server::new(model(&[0.0]));
        let mut mom = Server::new(model(&[0.0])).with_momentum(0.9);
        for _ in 0..5 {
            // clients keep reporting "server + 1"
            let target_p = model(&[plain.params[0][0] + 1.0]);
            let target_m = model(&[mom.params[0][0] + 1.0]);
            plain.aggregate(&[target_p], None).unwrap();
            mom.aggregate(&[target_m], None).unwrap();
        }
        assert!(mom.params[0][0] > plain.params[0][0]);
    }

    #[test]
    fn shape_mismatches_rejected() {
        let mut s = Server::new(model(&[0.0, 0.0]));
        assert!(s.aggregate(&[], None).is_err());
        assert!(s.aggregate(&[model(&[1.0])], None).is_err());
        assert!(s
            .aggregate(&[model(&[1.0, 2.0])], Some(&[1.0, 2.0]))
            .is_err());
        assert!(s
            .aggregate(&[model(&[1.0, 2.0])], Some(&[0.0]))
            .is_err());
    }

    #[test]
    fn aggregation_deterministic_in_f64() {
        // ordering of clients must not change the result beyond f64 assoc.
        let mut s1 = Server::new(model(&[0.0; 4]));
        let mut s2 = Server::new(model(&[0.0; 4]));
        let a = model(&[0.125, -3.5, 1e-3, 7.25]);
        let b = model(&[4.5, 2.25, -1e-3, 0.5]);
        s1.aggregate(&[a.clone(), b.clone()], None).unwrap();
        s2.aggregate(&[b, a], None).unwrap();
        assert_eq!(s1.params, s2.params);
    }

    #[test]
    fn skip_round_advances_without_update() {
        let mut s = Server::new(model(&[1.5, -2.0]));
        let before = s.params.clone();
        s.skip_round();
        assert_eq!(s.round, 1);
        assert_eq!(s.params, before);
    }

    // -------- streaming path --------

    /// Irregular multi-variable client models + weights for the
    /// streaming-vs-reference comparisons.
    fn cohort(g: &mut Gen, clients: usize) -> (Vec<Vec<Vec<f32>>>, Vec<f64>) {
        let lens = [257usize, 64, 1000, 3];
        let models: Vec<Vec<Vec<f32>>> = (0..clients)
            .map(|_| {
                lens.iter()
                    .map(|&n| g.vec_normal(n, 0.5))
                    .collect()
            })
            .collect();
        let weights: Vec<f64> =
            (0..clients).map(|i| 1.0 + (i % 5) as f64).collect();
        (models, weights)
    }

    fn raw_wire(model: &[Vec<f32>]) -> Vec<u8> {
        let mut w = WireWriter::with_capacity(0);
        for v in model {
            w.raw(v);
        }
        w.finish()
    }

    #[test]
    fn streaming_model_path_is_bit_exact_vs_reference() {
        let mut g = Gen::new(11);
        let (models, weights) = cohort(&mut g, 7);
        let init: Vec<Vec<f32>> =
            models[0].iter().map(|v| vec![0.0f32; v.len()]).collect();

        let mut reference = Server::new(init.clone());
        reference.aggregate(&models, Some(&weights)).unwrap();

        let mut streaming = Server::new(init);
        let total: f64 = weights.iter().sum();
        let mut agg = StreamingAggregator::for_server(&streaming);
        for (m, &w) in models.iter().zip(&weights) {
            agg.accumulate_model(m, w / total).unwrap();
        }
        assert_eq!(agg.clients(), 7);
        agg.apply(&mut streaming).unwrap();

        assert_eq!(streaming.round, reference.round);
        for (a, b) in streaming.params.iter().zip(&reference.params) {
            assert_eq!(
                a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn streaming_wire_path_is_bit_exact_vs_reference() {
        // raw f32 frames decode losslessly, so the wire path must match the
        // reference exactly too (same client order, same weights)
        let mut g = Gen::new(12);
        let (models, weights) = cohort(&mut g, 5);
        let init: Vec<Vec<f32>> =
            models[0].iter().map(|v| vec![0.0f32; v.len()]).collect();

        let mut reference = Server::new(init.clone()).with_momentum(0.5);
        reference.aggregate(&models, Some(&weights)).unwrap();

        let mut streaming = Server::new(init).with_momentum(0.5);
        let total: f64 = weights.iter().sum();
        let mut agg = StreamingAggregator::for_server(&streaming);
        let mut scratch = Vec::new();
        for (m, &w) in models.iter().zip(&weights) {
            agg.accumulate_wire(&raw_wire(m), w / total, &mut scratch)
                .unwrap();
        }
        agg.apply(&mut streaming).unwrap();

        for (a, b) in streaming.params.iter().zip(&reference.params) {
            assert_eq!(
                a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn sharded_merge_matches_reference_within_tolerance() {
        let mut g = Gen::new(13);
        let (models, weights) = cohort(&mut g, 9);
        let init: Vec<Vec<f32>> =
            models[0].iter().map(|v| vec![0.0f32; v.len()]).collect();

        let mut reference = Server::new(init.clone());
        reference.aggregate(&models, Some(&weights)).unwrap();

        // 3 shards of 3 clients, merged in shard order
        let total: f64 = weights.iter().sum();
        let lens: Vec<usize> = init.iter().map(|v| v.len()).collect();
        let mut merged = StreamingAggregator::new(&lens);
        let mut scratch = Vec::new();
        for shard in 0..3 {
            let mut part = StreamingAggregator::new(&lens);
            for i in (shard * 3)..(shard * 3 + 3) {
                part.accumulate_wire(
                    &raw_wire(&models[i]),
                    weights[i] / total,
                    &mut scratch,
                )
                .unwrap();
            }
            merged.merge(part).unwrap();
        }
        assert_eq!(merged.clients(), 9);
        let mut streaming = Server::new(init);
        merged.apply(&mut streaming).unwrap();

        for (a, b) in streaming.params.iter().zip(&reference.params) {
            for (x, y) in a.iter().zip(b) {
                assert!(
                    (x - y).abs() <= 1e-6,
                    "sharded {x} vs reference {y}"
                );
            }
        }
    }

    #[test]
    fn accumulator_memory_is_cohort_independent() {
        let lens = [500usize, 32];
        let mut g = Gen::new(14);
        let mut sizes = Vec::new();
        for clients in [2usize, 64] {
            let mut agg = StreamingAggregator::new(&lens);
            let mut scratch = Vec::new();
            for _ in 0..clients {
                let m: Vec<Vec<f32>> =
                    lens.iter().map(|&n| g.vec_normal(n, 0.1)).collect();
                agg.accumulate_wire(
                    &raw_wire(&m),
                    1.0 / clients as f64,
                    &mut scratch,
                )
                .unwrap();
            }
            sizes.push(agg.memory_bytes());
        }
        assert_eq!(sizes[0], sizes[1], "accumulator must not grow with cohort");
        assert_eq!(sizes[0], (500 + 32) * 8);
    }

    #[test]
    fn streaming_rejects_mismatches_and_bad_weights() {
        let lens = [4usize];
        let mut agg = StreamingAggregator::new(&lens);
        // wrong variable count
        assert!(agg
            .accumulate_model(&[vec![0.0; 4], vec![0.0; 2]], 0.5)
            .is_err());
        // wrong variable length
        assert!(agg.accumulate_model(&[vec![0.0; 3]], 0.5).is_err());
        // wire frame with wrong length
        let mut scratch = Vec::new();
        let wire = raw_wire(&[vec![0.0f32; 5]]);
        assert!(agg.accumulate_wire(&wire, 0.5, &mut scratch).is_err());
        // empty apply
        let mut s = Server::new(vec![vec![0.0f32; 4]]);
        assert!(StreamingAggregator::new(&lens).apply(&mut s).is_err());
        // unnormalized weights
        let mut agg = StreamingAggregator::new(&lens);
        agg.accumulate_model(&[vec![1.0f32; 4]], 0.4).unwrap();
        assert!(agg.apply(&mut s).is_err());
        // shape mismatch vs server
        let mut agg = StreamingAggregator::new(&[3]);
        agg.accumulate_model(&[vec![1.0f32; 3]], 1.0).unwrap();
        assert!(agg.apply(&mut s).is_err());
        assert_eq!(s.round, 0, "failed applies must not advance the round");
    }

    fn raw_wire_v2(model: &[Vec<f32>], nonce: u64) -> Vec<u8> {
        let mut w = WireWriter::with_integrity(0, nonce);
        for v in model {
            w.raw(v);
        }
        w.finish()
    }

    #[test]
    fn checked_fold_rejects_corruption_without_touching_sums() {
        let mut g = Gen::new(15);
        let m: Vec<Vec<f32>> = vec![g.vec_normal(64, 0.5)];
        let wire = raw_wire_v2(&m, 77);
        let mut agg = StreamingAggregator::new(&[64]);
        let mut scratch = Vec::new();
        let mut ledger = codec::NonceLedger::new(16);

        // a corrupt frame is rejected, never folded — even when the flip
        // sits mid-payload where a progressive fold would already have
        // mutated the sums
        let mut bad = wire.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x10;
        let v = agg
            .accumulate_wire_checked(&bad, 0.5, &mut scratch, &mut ledger)
            .unwrap();
        assert!(!v.accepted(), "corrupt frame must be rejected");
        assert_eq!(agg.clients(), 0);
        assert_eq!(agg.total_weight(), 0.0);

        // the clean frame folds; its replay is a duplicate
        assert!(agg
            .accumulate_wire_checked(&wire, 0.5, &mut scratch, &mut ledger)
            .unwrap()
            .accepted());
        assert_eq!(agg.clients(), 1);
        let v = agg
            .accumulate_wire_checked(&wire, 0.5, &mut scratch, &mut ledger)
            .unwrap();
        match v {
            WireVerdict::Rejected(codec::DecodeError::DuplicateNonce(77)) => {}
            other => panic!("expected duplicate-nonce rejection, got {other:?}"),
        }
        assert_eq!(agg.clients(), 1, "duplicate must not fold");

        // v1 frames (no nonce) pass the ledger freely
        let v1 = raw_wire(&m);
        assert!(agg
            .accumulate_wire_checked(&v1, 0.5, &mut scratch, &mut ledger)
            .unwrap()
            .accepted());
        assert_eq!(agg.clients(), 2);
    }

    #[test]
    fn delta_ack_advances_only_on_accepted_folds() {
        // regression for the ack/retry contract: a chaos-corrupted or
        // otherwise rejected v3 frame must leave BOTH the sums and the
        // delta ack state untouched; the bounded retries of one update
        // share a nonce, so the clean retry still folds and only then
        // does the ack advance. A replayed accepted frame is rejected by
        // the nonce ledger and must not advance the ack again.
        use crate::fl::chaos::{apply_fault, FaultKind, PlannedFault};
        use crate::omc::delta::{AckLedger, DeltaBase};
        use crate::testkit::{encode_frame_v3, perturbed_model, sample_wire_model};

        let mut g = Gen::new(21);
        let base_model = sample_wire_model(&mut g);
        let cur = perturbed_model(&mut g, &base_model, 3);
        let base = DeltaBase::from_model(7, &base_model);
        let (wire, _saved) = encode_frame_v3(&cur, 42, &base);

        // aggregator shaped like the sample model's decompressed vars
        let lens: Vec<usize> = crate::testkit::decode_all_based(&wire, Some(&base))
            .unwrap()
            .iter()
            .map(|v| v.len())
            .collect();
        let mut agg = StreamingAggregator::new(&lens);
        let mut scratch = Vec::new();
        let mut ledger = codec::NonceLedger::new(16);
        let mut acks = AckLedger::new();
        let cid = 3u64;

        // attempt 1: chaos bit-flip → rejected, no fold, no ack movement
        let mut attempt = wire.clone();
        apply_fault(
            &PlannedFault { kind: FaultKind::BitFlip, param: 0x5EED },
            &mut attempt,
        );
        let v = agg
            .accumulate_wire_checked_based(&attempt, 0.5, &mut scratch, &mut ledger, Some(&base))
            .unwrap();
        if v.accepted() {
            acks.advance(cid, base.version);
        }
        assert!(!v.accepted(), "corrupt delta frame must be rejected");
        assert_eq!(agg.clients(), 0);
        assert_eq!(acks.last(cid), None, "rejected frame advanced the ack");

        // attempt 2: chaos truncation → same story
        let mut attempt = wire.clone();
        apply_fault(
            &PlannedFault { kind: FaultKind::Truncate, param: 0xBAD },
            &mut attempt,
        );
        let v = agg
            .accumulate_wire_checked_based(&attempt, 0.5, &mut scratch, &mut ledger, Some(&base))
            .unwrap();
        if v.accepted() {
            acks.advance(cid, base.version);
        }
        assert!(!v.accepted());
        assert_eq!(acks.last(cid), None);

        // clean retry shares the nonce (the corrupt attempts never reached
        // the ledger) → folds, and only now does the ack advance
        let v = agg
            .accumulate_wire_checked_based(&wire, 0.5, &mut scratch, &mut ledger, Some(&base))
            .unwrap();
        if v.accepted() {
            acks.advance(cid, base.version);
        }
        assert!(v.accepted(), "clean retry with the shared nonce must fold");
        assert_eq!(agg.clients(), 1);
        assert_eq!(acks.last(cid), Some(7));

        // duplicate replay → nonce rejection, ack unchanged
        let v = agg
            .accumulate_wire_checked_based(&wire, 0.5, &mut scratch, &mut ledger, Some(&base))
            .unwrap();
        if v.accepted() {
            acks.advance(cid, base.version);
        }
        match v {
            WireVerdict::Rejected(codec::DecodeError::DuplicateNonce(42)) => {}
            other => panic!("expected duplicate-nonce rejection, got {other:?}"),
        }
        assert_eq!(agg.clients(), 1);
        assert_eq!(acks.last(cid), Some(7));

        // a frame acknowledging a base the server no longer holds is
        // rejected before any fold — its ack must not move either
        let newer = DeltaBase::from_model(9, &base_model);
        let (stale, _) = encode_frame_v3(&cur, 43, &newer);
        let v = agg
            .accumulate_wire_checked_based(&stale, 0.5, &mut scratch, &mut ledger, Some(&base))
            .unwrap();
        if v.accepted() {
            acks.advance(cid, newer.version);
        }
        match v {
            WireVerdict::Rejected(codec::DecodeError::BaseVersionMismatch {
                frame: 9,
                have: 7,
            }) => {}
            other => panic!("expected base-version rejection, got {other:?}"),
        }
        assert_eq!(acks.last(cid), Some(7));
        // and the ack itself is monotonic: a replayed older base version
        // can never roll an acknowledged client backwards
        assert!(acks.advance(cid, 9));
        assert!(!acks.advance(cid, 7));
        assert_eq!(acks.last(cid), Some(9));
    }

    /// One-raw-one-sparse uplink frame over 8+6 elements.
    fn sparse_wire(
        raw: &[f32],
        gathered: &[f32],
        indices: &[u32],
        n: usize,
        nonce: u64,
    ) -> Vec<u8> {
        use crate::omc::format::FloatFormat;
        let fmt: FloatFormat = "S1E4M14".parse().unwrap();
        let mut w = WireWriter::with_integrity(0, nonce);
        w.raw(raw);
        w.sparse_values(gathered, indices, n, fmt, true);
        w.finish()
    }

    #[test]
    fn sparse_fold_matches_base_plus_scatter_bitwise() {
        let mut g = Gen::new(31);
        let raw = g.vec_normal(8, 0.5);
        let base_var = g.vec_normal(6, 0.5);
        let gathered = [0.75f32, -0.5, 0.25];
        let indices = [1u32, 2, 5];
        let wire = sparse_wire(&raw, &gathered, &indices, 6, 9);
        let sparse_base = vec![Vec::new(), base_var.clone()];

        let mut agg = StreamingAggregator::new(&[8, 6]);
        let mut scratch = Vec::new();
        let wc = 1.0f64;
        agg.accumulate_wire_with(&wire, wc, &mut scratch, None, Some(&sparse_base))
            .unwrap();
        assert_eq!(agg.clients(), 1);

        // expected: wc·base over the whole variable, then wc·value at the
        // selected coordinates — the exact f64 ops of the sparse fold,
        // using the quantized gathered values the frame actually carries
        let mut vals = Vec::new();
        let mut dense_update = vec![0.0f32; 6];
        codec::for_each_var(&wire, |vi, view| {
            if vi == 1 {
                view.decompress_into(&mut vals);
                dense_update.copy_from_slice(&vals);
            }
            Ok(())
        })
        .unwrap();
        let mut expected = vec![vec![0.0f64; 8], vec![0.0f64; 6]];
        for (a, &x) in expected[0].iter_mut().zip(&raw) {
            *a += wc * x as f64;
        }
        for (a, &x) in expected[1].iter_mut().zip(&base_var) {
            *a += wc * x as f64;
        }
        for &j in &indices {
            expected[1][j as usize] += wc * dense_update[j as usize] as f64;
        }
        let mut got = Server::new(vec![vec![0.0f32; 8], vec![0.0f32; 6]]);
        agg.apply(&mut got).unwrap();
        let mut want = Server::new(vec![vec![0.0f32; 8], vec![0.0f32; 6]]);
        want.apply_mean(expected);
        for (a, b) in got.params.iter().zip(&want.params) {
            assert_eq!(
                a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn sparse_fold_without_base_is_a_harness_error() {
        let mut g = Gen::new(32);
        let raw = g.vec_normal(8, 0.5);
        let wire = sparse_wire(&raw, &[1.0, 2.0], &[0, 3], 6, 10);
        let mut agg = StreamingAggregator::new(&[8, 6]);
        let mut scratch = Vec::new();
        // no sparse base at all
        assert!(agg
            .accumulate_wire_with(&wire, 1.0, &mut scratch, None, None)
            .is_err());
        // base with the wrong variable length
        let short = vec![Vec::new(), vec![0.0f32; 3]];
        assert!(agg
            .accumulate_wire_with(&wire, 1.0, &mut scratch, None, Some(&short))
            .is_err());
    }
}
