//! Server state and FedAvg aggregation.
//!
//! The server keeps the global model in full precision (OMC targets
//! *client* memory and the *transport*; the paper's server receives
//! decompressed updates and aggregates them). Aggregation is weighted
//! FedAvg over client models, with optional server momentum (FedAvgM) —
//! off by default, matching the paper's setup of plain averaging.

use anyhow::Result;

/// The server's global model + optimizer state.
#[derive(Clone, Debug)]
pub struct Server {
    /// full-precision master copy, one Vec per manifest variable
    pub params: Vec<Vec<f32>>,
    /// momentum buffers (allocated lazily when momentum > 0)
    velocity: Option<Vec<Vec<f32>>>,
    pub momentum: f32,
    pub round: usize,
}

impl Server {
    pub fn new(params: Vec<Vec<f32>>) -> Self {
        Self {
            params,
            velocity: None,
            momentum: 0.0,
            round: 0,
        }
    }

    pub fn with_momentum(mut self, m: f32) -> Self {
        assert!((0.0..1.0).contains(&m), "momentum in [0,1)");
        self.momentum = m;
        self
    }

    pub fn num_params(&self) -> usize {
        self.params.iter().map(|v| v.len()).sum()
    }

    /// FedAvg: replace the global model with the weighted mean of client
    /// models. `weights` default to uniform; with momentum > 0 the weighted
    /// mean *delta* is applied through a velocity buffer instead.
    pub fn aggregate(
        &mut self,
        client_models: &[Vec<Vec<f32>>],
        weights: Option<&[f64]>,
    ) -> Result<()> {
        anyhow::ensure!(!client_models.is_empty(), "no client models to aggregate");
        let uniform = vec![1.0; client_models.len()];
        let w = weights.unwrap_or(&uniform);
        anyhow::ensure!(
            w.len() == client_models.len(),
            "weights/models length mismatch"
        );
        let total: f64 = w.iter().sum();
        anyhow::ensure!(total > 0.0, "non-positive total weight");
        for m in client_models {
            anyhow::ensure!(
                m.len() == self.params.len(),
                "client model has {} vars, server has {}",
                m.len(),
                self.params.len()
            );
        }

        // weighted mean, accumulated in f64 for determinism across client
        // counts
        let mut mean: Vec<Vec<f64>> = self
            .params
            .iter()
            .map(|v| vec![0.0f64; v.len()])
            .collect();
        for (ci, m) in client_models.iter().enumerate() {
            let wc = w[ci] / total;
            for (vi, var) in m.iter().enumerate() {
                anyhow::ensure!(
                    var.len() == self.params[vi].len(),
                    "variable {vi} length mismatch"
                );
                let acc = &mut mean[vi];
                for (a, &x) in acc.iter_mut().zip(var) {
                    *a += wc * x as f64;
                }
            }
        }

        if self.momentum > 0.0 {
            let mom = self.momentum as f64;
            let vel = self.velocity.get_or_insert_with(|| {
                self.params.iter().map(|v| vec![0.0f32; v.len()]).collect()
            });
            for (vi, var) in self.params.iter_mut().enumerate() {
                for (ei, p) in var.iter_mut().enumerate() {
                    let delta = mean[vi][ei] - *p as f64;
                    let v = mom * vel[vi][ei] as f64 + delta;
                    vel[vi][ei] = v as f32;
                    *p = (*p as f64 + v) as f32;
                }
            }
        } else {
            for (vi, var) in self.params.iter_mut().enumerate() {
                for (ei, p) in var.iter_mut().enumerate() {
                    *p = mean[vi][ei] as f32;
                }
            }
        }
        self.round += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(vals: &[f32]) -> Vec<Vec<f32>> {
        vec![vals.to_vec()]
    }

    #[test]
    fn uniform_average() {
        let mut s = Server::new(model(&[0.0, 0.0]));
        s.aggregate(&[model(&[1.0, 3.0]), model(&[3.0, 5.0])], None)
            .unwrap();
        assert_eq!(s.params[0], vec![2.0, 4.0]);
        assert_eq!(s.round, 1);
    }

    #[test]
    fn weighted_average() {
        let mut s = Server::new(model(&[0.0]));
        s.aggregate(
            &[model(&[1.0]), model(&[4.0])],
            Some(&[3.0, 1.0]),
        )
        .unwrap();
        assert!((s.params[0][0] - 1.75).abs() < 1e-6);
    }

    #[test]
    fn single_client_replaces() {
        let mut s = Server::new(model(&[9.0, 9.0]));
        s.aggregate(&[model(&[1.0, 2.0])], None).unwrap();
        assert_eq!(s.params[0], vec![1.0, 2.0]);
    }

    #[test]
    fn momentum_accelerates_along_consistent_direction() {
        let mut plain = Server::new(model(&[0.0]));
        let mut mom = Server::new(model(&[0.0])).with_momentum(0.9);
        for _ in 0..5 {
            // clients keep reporting "server + 1"
            let target_p = model(&[plain.params[0][0] + 1.0]);
            let target_m = model(&[mom.params[0][0] + 1.0]);
            plain.aggregate(&[target_p], None).unwrap();
            mom.aggregate(&[target_m], None).unwrap();
        }
        assert!(mom.params[0][0] > plain.params[0][0]);
    }

    #[test]
    fn shape_mismatches_rejected() {
        let mut s = Server::new(model(&[0.0, 0.0]));
        assert!(s.aggregate(&[], None).is_err());
        assert!(s.aggregate(&[model(&[1.0])], None).is_err());
        assert!(s
            .aggregate(&[model(&[1.0, 2.0])], Some(&[1.0, 2.0]))
            .is_err());
        assert!(s
            .aggregate(&[model(&[1.0, 2.0])], Some(&[0.0]))
            .is_err());
    }

    #[test]
    fn aggregation_deterministic_in_f64() {
        // ordering of clients must not change the result beyond f64 assoc.
        let mut s1 = Server::new(model(&[0.0; 4]));
        let mut s2 = Server::new(model(&[0.0; 4]));
        let a = model(&[0.125, -3.5, 1e-3, 7.25]);
        let b = model(&[4.5, 2.25, -1e-3, 0.5]);
        s1.aggregate(&[a.clone(), b.clone()], None).unwrap();
        s2.aggregate(&[b, a], None).unwrap();
        assert_eq!(s1.params, s2.params);
    }
}
