//! Scoped thread pool for simulated federated clients (no `tokio`/`rayon`
//! offline).
//!
//! The coordinator dispatches one job per sampled client per round. Jobs are
//! closures returning `R`; `scope_map` preserves input order in the output.
//! On this 1-core testbed the pool mostly provides *structural* concurrency
//! (and exercises the same code path a many-core host would use), sized by
//! `available_parallelism`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Mutex;
use std::thread;

/// Run `f(i, &items[i])` for every item on `workers` threads, collecting
/// results in input order. Panics in workers propagate as `Err`.
/// (Thin wrapper over [`scope_map_send`]: `&T` is `Send` when `T: Sync`.)
pub fn scope_map<T, R, F>(items: &[T], workers: usize, f: F) -> anyhow::Result<Vec<R>>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    scope_map_send(items.iter().collect(), workers, |i, t| f(i, t))
}

/// Like [`scope_map`], but items are consumed *by value* (`T: Send`, not
/// `Sync`). This is what lets the block codec hand each worker a disjoint
/// `(&[f32], &mut [u8])` span of one large tensor: mutable slices are
/// `Send` but not `Sync`, so they cannot go through `scope_map`'s shared
/// `&[T]`. Results come back in input order; worker panics become `Err`.
pub fn scope_map_send<T, R, F>(items: Vec<T>, workers: usize, f: F) -> anyhow::Result<Vec<R>>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return Ok(items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect());
    }
    let slots: Vec<Mutex<Option<T>>> =
        items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, thread::Result<R>)>();

    thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let (f, slots, next) = (&f, &slots, &next);
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let item = slots[i].lock().unwrap().take().unwrap();
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                    || f(i, item),
                ));
                if tx.send((i, out)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut panicked = false;
        for (i, res) in rx {
            match res {
                Ok(r) => out[i] = Some(r),
                Err(_) => panicked = true,
            }
        }
        if panicked {
            anyhow::bail!("worker job panicked");
        }
        Ok(out.into_iter().map(|s| s.unwrap()).collect())
    })
}

/// Run items split into contiguous chunks — one chunk per worker — where
/// each worker builds private state once (`init`) and threads `&mut` state
/// through every item of its chunk. Results come back in input order.
///
/// This is the shape the sweep engine needs: cells are independent jobs,
/// but each worker keeps a warmed `RoundEngine` (codec buffer pools)
/// across the cells it runs, which a plain [`scope_map_send`] cannot
/// express (no per-worker identity). The chunking is contiguous, so for a
/// fixed item order the mapping of item → result index is independent of
/// the worker count.
pub fn scope_map_chunked<T, R, S, FI, F>(
    items: Vec<T>,
    workers: usize,
    init: FI,
    f: F,
) -> anyhow::Result<Vec<R>>
where
    T: Send,
    R: Send,
    FI: Fn() -> S + Sync,
    F: Fn(usize, T, &mut S) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        let mut state = init();
        return Ok(items
            .into_iter()
            .enumerate()
            .map(|(i, t)| f(i, t, &mut state))
            .collect());
    }
    let chunk = n.div_ceil(workers);
    let mut chunks: Vec<(usize, Vec<T>)> = Vec::with_capacity(workers);
    let mut it = items.into_iter();
    let mut base = 0usize;
    loop {
        let c: Vec<T> = it.by_ref().take(chunk).collect();
        if c.is_empty() {
            break;
        }
        base += c.len();
        chunks.push((base - c.len(), c));
    }
    let nested = scope_map_send(chunks, workers, |_, (start, items)| {
        let mut state = init();
        items
            .into_iter()
            .enumerate()
            .map(|(k, t)| f(start + k, t, &mut state))
            .collect::<Vec<R>>()
    })?;
    Ok(nested.into_iter().flatten().collect())
}

/// Default worker count: one per available core (min 1).
pub fn default_workers() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn maps_in_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = scope_map(&items, 4, |_, &x| x * 2).unwrap();
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_item_exactly_once() {
        let counter = AtomicUsize::new(0);
        let items: Vec<u32> = (0..57).collect();
        let _ = scope_map(&items, 8, |_, _| {
            counter.fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 57);
    }

    #[test]
    fn empty_input() {
        let out: Vec<u32> = scope_map(&Vec::<u32>::new(), 4, |_, _| 1).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_matches_many() {
        let items: Vec<u64> = (0..31).collect();
        let a = scope_map(&items, 1, |i, &x| x + i as u64).unwrap();
        let b = scope_map(&items, 7, |i, &x| x + i as u64).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn panic_propagates_as_error() {
        let items = vec![1, 2, 3];
        let r = scope_map(&items, 2, |_, &x| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
        assert!(r.is_err());
    }

    #[test]
    fn more_workers_than_items() {
        let items = vec![5u32];
        let out = scope_map(&items, 16, |_, &x| x).unwrap();
        assert_eq!(out, vec![5]);
    }

    #[test]
    fn send_variant_consumes_mutable_slices() {
        // the parallel-codec use case: disjoint &mut spans of one buffer
        let mut buf = vec![0u32; 64];
        let items: Vec<(usize, &mut [u32])> =
            buf.chunks_mut(16).enumerate().collect();
        scope_map_send(items, 4, |_, (ci, chunk)| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = (ci * 16 + j) as u32;
            }
        })
        .unwrap();
        assert_eq!(buf, (0..64).collect::<Vec<u32>>());
    }

    #[test]
    fn chunked_preserves_order_and_reuses_state() {
        let items: Vec<u64> = (0..23).collect();
        for workers in [1usize, 3, 8, 64] {
            // state counts how many items this worker has seen; results
            // must be ordered by input index regardless of worker count
            let out = scope_map_chunked(
                items.clone(),
                workers,
                || 0usize,
                |i, x, seen| {
                    *seen += 1;
                    (i as u64, x, *seen)
                },
            )
            .unwrap();
            assert_eq!(out.len(), 23);
            for (i, (idx, x, seen)) in out.iter().enumerate() {
                assert_eq!(*idx, i as u64);
                assert_eq!(*x, i as u64);
                assert!(*seen >= 1);
            }
            // contiguous chunking: within a chunk the per-worker counter
            // increments by one per item
            if workers == 1 {
                assert!(out.iter().enumerate().all(|(i, r)| r.2 == i + 1));
            }
        }
        let empty: Vec<u8> =
            scope_map_chunked(Vec::<u8>::new(), 4, || (), |_, x, _| x).unwrap();
        assert!(empty.is_empty());
    }

    #[test]
    fn chunked_propagates_panics() {
        let r = scope_map_chunked(vec![1, 2, 3], 2, || (), |_, x, _| {
            if x == 3 {
                panic!("boom");
            }
            x
        });
        assert!(r.is_err());
    }

    #[test]
    fn send_variant_matches_serial_and_propagates_panics() {
        let items: Vec<u64> = (0..57).collect();
        let a = scope_map_send(items.clone(), 1, |i, x| x * 2 + i as u64).unwrap();
        let b = scope_map_send(items, 6, |i, x| x * 2 + i as u64).unwrap();
        assert_eq!(a, b);
        let r = scope_map_send(vec![1, 2, 3], 2, |_, x| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
        assert!(r.is_err());
        let empty: Vec<u32> = scope_map_send(Vec::<u32>::new(), 3, |_, x| x).unwrap();
        assert!(empty.is_empty());
    }
}
