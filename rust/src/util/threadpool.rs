//! Scoped thread pool for simulated federated clients (no `tokio`/`rayon`
//! offline).
//!
//! The coordinator dispatches one job per sampled client per round. Jobs are
//! closures returning `R`; `scope_map` preserves input order in the output.
//! On this 1-core testbed the pool mostly provides *structural* concurrency
//! (and exercises the same code path a many-core host would use), sized by
//! `available_parallelism`.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Run `f(i, &items[i])` for every item on `workers` threads, collecting
/// results in input order. Panics in workers propagate as `Err`.
pub fn scope_map<T, R, F>(items: &[T], workers: usize, f: F) -> anyhow::Result<Vec<R>>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let workers = workers.max(1).min(n);
    let next = Arc::new(Mutex::new(0usize));
    let (tx, rx) = mpsc::channel::<(usize, thread::Result<R>)>();

    thread::scope(|scope| {
        for _ in 0..workers {
            let next = Arc::clone(&next);
            let tx = tx.clone();
            let f = &f;
            scope.spawn(move || loop {
                let i = {
                    let mut g = next.lock().unwrap();
                    if *g >= n {
                        break;
                    }
                    let i = *g;
                    *g += 1;
                    i
                };
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    f(i, &items[i])
                }));
                if tx.send((i, out)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut panicked = false;
        for (i, res) in rx {
            match res {
                Ok(r) => slots[i] = Some(r),
                Err(_) => panicked = true,
            }
        }
        if panicked {
            anyhow::bail!("worker job panicked");
        }
        Ok(slots.into_iter().map(|s| s.unwrap()).collect())
    })
}

/// Default worker count: one per available core (min 1).
pub fn default_workers() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn maps_in_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = scope_map(&items, 4, |_, &x| x * 2).unwrap();
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_item_exactly_once() {
        let counter = AtomicUsize::new(0);
        let items: Vec<u32> = (0..57).collect();
        let _ = scope_map(&items, 8, |_, _| {
            counter.fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 57);
    }

    #[test]
    fn empty_input() {
        let out: Vec<u32> = scope_map(&Vec::<u32>::new(), 4, |_, _| 1).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_matches_many() {
        let items: Vec<u64> = (0..31).collect();
        let a = scope_map(&items, 1, |i, &x| x + i as u64).unwrap();
        let b = scope_map(&items, 7, |i, &x| x + i as u64).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn panic_propagates_as_error() {
        let items = vec![1, 2, 3];
        let r = scope_map(&items, 2, |_, &x| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
        assert!(r.is_err());
    }

    #[test]
    fn more_workers_than_items() {
        let items = vec![5u32];
        let out = scope_map(&items, 16, |_, &x| x).unwrap();
        assert_eq!(out, vec![5]);
    }
}
