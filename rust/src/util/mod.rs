//! Self-contained substrate utilities.
//!
//! The build environment is fully offline, so the usual ecosystem crates
//! (`rand`, `serde`, `clap`, `tokio`, …) are unavailable; these modules are
//! small, tested replacements for exactly the slices of functionality the
//! coordinator needs.

pub mod arena;
pub mod cli;
pub mod json;
pub mod logging;
pub mod rng;
pub mod simd;
pub mod threadpool;
pub mod toml;
