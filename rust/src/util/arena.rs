//! Cross-thread object arenas for the serving hot path.
//!
//! The virtual-time engines (`fl::round`, `fl::async_round`) recycle their
//! scratch buffers by construction: one coordinator thread owns a
//! `RoundScratch` and hands slices of it to short-lived worker scopes. The
//! wall-clock serving engine (`fl::serve`) has no such owner — workers run
//! for the life of the process and frame buffers cross threads (worker →
//! uplink queue → server fold → back to a worker) — so without pooling,
//! every uplink pays a fresh downlink-frame allocation and every fold drops
//! a wire buffer on the floor.
//!
//! [`Arena<T>`] is the shared free list behind that recycling: `acquire`
//! pops a recycled object (or builds a fresh `T::default()`), `release`
//! reclaims it ([`Reclaim::reclaim`] clears *length*, never capacity) and
//! pushes it back. The arena counts acquires / fresh constructions /
//! recycles so benches and the serve report can assert the steady state
//! allocates nothing ([`ArenaStats`]; `benches/bench_serve.rs` runs the
//! arena-on vs arena-off A/B). A disabled arena (`Arena::disabled`) keeps
//! the same API but never pools — every acquire is fresh — which is the
//! control arm of that A/B and the `serve.arena = false` escape hatch.
//!
//! The free list is a plain `Mutex<Vec<T>>`: acquire/release are two
//! pointer moves under an uncontended lock, orders of magnitude below the
//! cost of the frame encode/decode they bracket. The lock-free machinery
//! lives where it matters — snapshot publication (`omc::store`), which
//! sits on every downlink read.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Reset an object for reuse: drop contents, keep capacity.
pub trait Reclaim {
    /// Clear lengths/state so the object is indistinguishable from freshly
    /// constructed *to its user*, while retaining backing allocations.
    fn reclaim(&mut self);
}

impl Reclaim for Vec<u8> {
    fn reclaim(&mut self) {
        self.clear();
    }
}

impl Reclaim for Vec<f32> {
    fn reclaim(&mut self) {
        self.clear();
    }
}

/// Allocation counters for an [`Arena`] (monotonic over its lifetime).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// total `acquire` calls
    pub acquires: u64,
    /// acquires served by constructing a fresh object (the allocation count
    /// the serve bench A/B asserts on)
    pub fresh: u64,
    /// acquires served from the free list
    pub recycled: u64,
}

/// A shared pool of reusable objects (see the module docs).
#[derive(Debug)]
pub struct Arena<T> {
    free: Mutex<Vec<T>>,
    enabled: bool,
    acquires: AtomicU64,
    fresh: AtomicU64,
    recycled: AtomicU64,
}

impl<T: Reclaim + Default> Arena<T> {
    /// An empty, enabled arena.
    pub fn new() -> Self {
        Self::with_enabled(true)
    }

    /// An arena that never pools: every acquire constructs fresh and every
    /// release drops. Same API, zero reuse — the A/B control arm.
    pub fn disabled() -> Self {
        Self::with_enabled(false)
    }

    /// `new()` when `enabled`, `disabled()` otherwise.
    pub fn with_enabled(enabled: bool) -> Self {
        Self {
            free: Mutex::new(Vec::new()),
            enabled,
            acquires: AtomicU64::new(0),
            fresh: AtomicU64::new(0),
            recycled: AtomicU64::new(0),
        }
    }

    /// Whether releases are pooled (false for the control arm).
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Pop a recycled object, or construct a fresh `T::default()`.
    pub fn acquire(&self) -> T {
        self.acquires.fetch_add(1, Ordering::Relaxed);
        if self.enabled {
            if let Some(obj) = self.free.lock().unwrap().pop() {
                self.recycled.fetch_add(1, Ordering::Relaxed);
                return obj;
            }
        }
        self.fresh.fetch_add(1, Ordering::Relaxed);
        T::default()
    }

    /// Reclaim `obj` (length cleared, capacity kept) and return it to the
    /// pool. A disabled arena drops it instead.
    pub fn release(&self, mut obj: T) {
        if !self.enabled {
            return;
        }
        obj.reclaim();
        self.free.lock().unwrap().push(obj);
    }

    /// Objects currently sitting in the free list.
    pub fn pooled(&self) -> usize {
        self.free.lock().unwrap().len()
    }

    /// Lifetime allocation counters.
    pub fn stats(&self) -> ArenaStats {
        ArenaStats {
            acquires: self.acquires.load(Ordering::Relaxed),
            fresh: self.fresh.load(Ordering::Relaxed),
            recycled: self.recycled.load(Ordering::Relaxed),
        }
    }
}

impl<T: Reclaim + Default> Default for Arena<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn recycles_capacity_and_counts() {
        let arena: Arena<Vec<u8>> = Arena::new();
        let mut a = arena.acquire();
        a.extend_from_slice(&[1, 2, 3, 4]);
        let cap = a.capacity();
        let ptr = a.as_ptr();
        arena.release(a);
        assert_eq!(arena.pooled(), 1);
        let b = arena.acquire();
        // reclaimed: empty to the user, same backing allocation
        assert!(b.is_empty());
        assert_eq!(b.capacity(), cap);
        assert_eq!(b.as_ptr(), ptr);
        let s = arena.stats();
        assert_eq!(
            s,
            ArenaStats {
                acquires: 2,
                fresh: 1,
                recycled: 1
            }
        );
    }

    #[test]
    fn disabled_arena_never_pools() {
        let arena: Arena<Vec<f32>> = Arena::disabled();
        assert!(!arena.is_enabled());
        let mut a = arena.acquire();
        a.push(1.0);
        arena.release(a);
        assert_eq!(arena.pooled(), 0);
        let _ = arena.acquire();
        let s = arena.stats();
        assert_eq!(s.acquires, 2);
        assert_eq!(s.fresh, 2);
        assert_eq!(s.recycled, 0);
    }

    #[test]
    fn concurrent_acquire_release_conserves_objects() {
        let arena: Arc<Arena<Vec<u8>>> = Arc::new(Arena::new());
        let threads = 4;
        let per = 100;
        std::thread::scope(|scope| {
            for t in 0..threads {
                let arena = Arc::clone(&arena);
                scope.spawn(move || {
                    for i in 0..per {
                        let mut buf = arena.acquire();
                        buf.extend_from_slice(&[t as u8; 32]);
                        if i % 3 == 0 {
                            std::thread::yield_now();
                        }
                        arena.release(buf);
                    }
                });
            }
        });
        let s = arena.stats();
        assert_eq!(s.acquires, (threads * per) as u64);
        assert_eq!(s.fresh + s.recycled, s.acquires);
        // every fresh object was released, so the pool holds exactly them
        assert_eq!(arena.pooled() as u64, s.fresh);
        // steady state recycles: far fewer fresh constructions than acquires
        assert!(s.fresh <= threads as u64);
    }
}
