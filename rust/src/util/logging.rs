//! Leveled stderr logger with wall-clock timestamps (no `tracing` offline).
//!
//! Level is process-global, settable from the CLI (`--log-level`) or the
//! `OMC_LOG` environment variable; default `info`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn init_from_env() {
    if let Ok(v) = std::env::var("OMC_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
}

pub fn enabled(l: Level) -> bool {
    l as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let now = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    let secs = now.as_secs();
    let ms = now.subsec_millis();
    // hh:mm:ss.mmm in UTC — enough for correlating a single-host run
    let (h, m, s) = ((secs / 3600) % 24, (secs / 60) % 60, secs % 60);
    eprintln!("{h:02}:{m:02}:{s:02}.{ms:03} {} [{module}] {msg}", l.tag());
}

#[macro_export]
macro_rules! log_error {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($t)*)) };
}

#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($t)*)) };
}

#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($t)*)) };
}

#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("TRACE"), Some(Level::Trace));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
