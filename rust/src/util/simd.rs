//! Runtime-dispatched SIMD kernel layer for the OMC hot loops (§Perf).
//!
//! Every simulated round pays the paper's "OMC tax" — decompress
//! `s·Ṽ + b` before each step, requantize + pack after it — and those
//! loops are pure lanewise f32 math. This module resolves, **once per
//! process**, a table of kernel function pointers ([`Kernels`]) for the
//! best instruction set the CPU offers and hands it to the `omc` kernel
//! call sites:
//!
//! * **avx2** — 8-lane f32 / 4-lane f64 kernels via `std::arch::x86_64`,
//!   selected when `is_x86_feature_detected!("avx2")` holds.
//! * **sse2** — 4-lane f32 / 2-lane f64 baseline; always available on
//!   `x86_64` (part of the base ISA).
//! * **scalar** — portable fallback, the only table on other
//!   architectures and the *reference semantics* for every other level.
//!
//! `OMC_FORCE_SCALAR=1` in the environment pins the dispatch to the
//! scalar table (checked once, at first use) — this is how CI proves the
//! sweep goldens are ISA-independent.
//!
//! # Determinism contract
//!
//! Vector kernels must be **bit-exact** against the scalar reference, so
//! results never depend on which ISA path ran:
//!
//! * The quantizer and the pow2-width encode/decode kernels are pure
//!   lanewise integer/bit math plus individually-rounded IEEE f32
//!   add/sub/mul — lanewise identical to scalar by construction. No FMA
//!   contraction is ever used (it would change the rounding).
//! * Reductions cannot be vectorized without reassociating the sum, so
//!   [`FitSums`] fixes a **virtual lane width** of [`FIT_LANES`] = 4
//!   f64 accumulators: element `i` always lands in lane `i % 4`, and
//!   [`FitSums::totals`] folds lanes in the fixed order
//!   `(l0 + l1) + (l2 + l3)` — every level (and the plain [`FitSums::push`]
//!   loop) performs the identical addition sequence.
//!
//! Bit-exactness across levels is property-tested in
//! `rust/tests/omc_kernels.rs`; the sweep byte-determinism gate in CI
//! additionally compares `OMC_FORCE_SCALAR=1` vs dispatched whole runs.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Resolved instruction-set level of a kernel table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    /// portable scalar fallback (reference semantics)
    Scalar,
    /// x86_64 baseline vectors (4-lane f32, 2-lane f64)
    Sse2,
    /// AVX2 (8-lane f32, 4-lane f64)
    Avx2,
}

impl Level {
    /// Short lowercase label for bench rows and logs.
    pub fn label(self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            Level::Sse2 => "sse2",
            Level::Avx2 => "avx2",
        }
    }
}

/// `fn(values, exp_bits, mant_bits, out)` — lanewise quantization.
pub type QuantizeFn = fn(&[f32], u32, u32, &mut [f32]);
/// In-place variant of [`QuantizeFn`].
pub type QuantizeInPlaceFn = fn(&mut [f32], u32, u32);
/// `fn(s, b, xs, out)` — the PVT affine `out[i] = s * xs[i] + b`.
pub type AxpbFn = fn(f32, f32, &[f32], &mut [f32]);
/// In-place variant of [`AxpbFn`].
pub type AxpbInPlaceFn = fn(f32, f32, &mut [f32]);
/// Accumulate `(v, t)` pairs into a [`FitSums`] (virtual-lane order).
pub type FitUpdateFn = fn(&mut FitSums, &[f32], &[f32]);
/// `fn(values, e, m, out)` — encode whole 256-value blocks of an 8- or
/// 16-bit-wide format straight to its byte image (codes are byte-lanes).
pub type PackPow2Fn = fn(&[f32], u32, u32, &mut [u8]);
/// `fn(bytes, e, m, quantum, map, out)` — decode whole blocks of an 8- or
/// 16-bit-wide format, applying `map = Some((s, b))` as a fused affine
/// (`None` preserves the decoded bits, including `-0.0`).
pub type UnpackPow2Fn = fn(&[u8], u32, u32, f32, Option<(f32, f32)>, &mut [f32]);
/// `fn(a, b, out)` — bytewise `out[i] = a[i] ^ b[i]` over equal-length
/// slices (the delta stage's XOR pass).
pub type XorBytesFn = fn(&[u8], &[u8], &mut [u8]);
/// `fn(bytes) -> u64` — OR-fold of a slice viewed as little-endian u64
/// words, zero-padding the final partial word (the delta stage's
/// block-width probe). Exact integer math: identical on every level.
pub type OrFoldFn = fn(&[u8]) -> u64;

/// One resolved kernel table. Obtain the process-wide table with
/// [`kernels`], or a specific level's table with [`kernels_for`].
pub struct Kernels {
    /// which ISA level this table implements
    pub level: Level,
    /// lanewise quantization (bit-exact vs `quantize_one_em`)
    pub quantize: QuantizeFn,
    /// in-place lanewise quantization
    pub quantize_in_place: QuantizeInPlaceFn,
    /// the PVT affine `s·x + b` (mul then add; never fused)
    pub axpb: AxpbFn,
    /// in-place PVT affine
    pub axpb_in_place: AxpbInPlaceFn,
    /// least-squares accumulator update (virtual-lane schedule)
    pub fit_update: FitUpdateFn,
    /// whole-block encode for 8/16-bit-wide formats (`None`: use the
    /// generic word kernels)
    pub pack_pow2: Option<PackPow2Fn>,
    /// whole-block decode for 8/16-bit-wide formats
    pub unpack_pow2: Option<UnpackPow2Fn>,
    /// bytewise XOR (delta stage)
    pub xor_bytes: XorBytesFn,
    /// OR-fold of little-endian u64 words (delta width probe)
    pub or_fold: OrFoldFn,
}

// ---------------------------------------------------------------------------
// dispatch
// ---------------------------------------------------------------------------

/// Bench/test override: 0 = none, otherwise a `Level` discriminant + 1.
static OVERRIDE: AtomicU8 = AtomicU8::new(0);

fn env_forces_scalar() -> bool {
    match std::env::var("OMC_FORCE_SCALAR") {
        Ok(v) => !v.is_empty() && v != "0",
        Err(_) => false,
    }
}

fn resolve() -> &'static Kernels {
    if env_forces_scalar() {
        return &SCALAR;
    }
    detect()
}

#[cfg(target_arch = "x86_64")]
fn detect() -> &'static Kernels {
    if is_x86_feature_detected!("avx2") {
        &x86::AVX2
    } else {
        &x86::SSE2
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn detect() -> &'static Kernels {
    &SCALAR
}

/// The process-wide kernel table: resolved once (honoring
/// `OMC_FORCE_SCALAR=1`), then handed out by reference. One relaxed
/// atomic load per call checks the bench-only [`force_level`] override.
pub fn kernels() -> &'static Kernels {
    static RESOLVED: OnceLock<&'static Kernels> = OnceLock::new();
    let resolved = *RESOLVED.get_or_init(resolve);
    match OVERRIDE.load(Ordering::Relaxed) {
        1 => &SCALAR,
        #[cfg(target_arch = "x86_64")]
        2 => &x86::SSE2,
        #[cfg(target_arch = "x86_64")]
        3 => &x86::AVX2,
        _ => resolved,
    }
}

/// The table for a specific level, or `None` when this CPU cannot run it
/// (tests iterate [`available_levels`] and compare every table against
/// [`Level::Scalar`] bit for bit).
pub fn kernels_for(level: Level) -> Option<&'static Kernels> {
    match level {
        Level::Scalar => Some(&SCALAR),
        #[cfg(target_arch = "x86_64")]
        Level::Sse2 => Some(&x86::SSE2),
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => {
            if is_x86_feature_detected!("avx2") {
                Some(&x86::AVX2)
            } else {
                None
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => None,
    }
}

/// Every level this CPU can execute (always includes `Scalar`).
pub fn available_levels() -> Vec<Level> {
    [Level::Scalar, Level::Sse2, Level::Avx2]
        .into_iter()
        .filter(|&l| kernels_for(l).is_some())
        .collect()
}

/// Force [`kernels`] to a specific level (benches use this to emit
/// scalar-vs-dispatched rows from one process). `None` restores the
/// resolved table. Returns `false` (and changes nothing) when the level
/// is not available on this CPU. Not for concurrent use: set it before
/// spawning workers.
pub fn force_level(level: Option<Level>) -> bool {
    match level {
        None => {
            OVERRIDE.store(0, Ordering::Relaxed);
            true
        }
        Some(l) => {
            if kernels_for(l).is_none() {
                return false;
            }
            let code = match l {
                Level::Scalar => 1,
                Level::Sse2 => 2,
                Level::Avx2 => 3,
            };
            OVERRIDE.store(code, Ordering::Relaxed);
            true
        }
    }
}

// ---------------------------------------------------------------------------
// scalar reference kernels
// ---------------------------------------------------------------------------

/// Quantize one f32 to the `S1E{e}M{m}` grid — the canonical scalar
/// algorithm every vector kernel must match bit for bit (see
/// `omc::quantize` for the paper-level contract): round-to-nearest-even
/// on the f32 encoding for the normal range, the exact additive trick
/// `(|x| + C) − C` for the subnormal range, saturation to max finite.
#[inline(always)]
pub fn quantize_one_em(x: f32, e: u32, m: u32) -> f32 {
    let u = x.to_bits();
    let sign = u & 0x8000_0000;
    let mag = u & 0x7FFF_FFFF;

    let bexp = (mag >> 23) as i32;
    let unb = bexp.max(1) - 127;
    let bias_f = (1i32 << (e - 1)) - 1;
    let min_normal_unb = 1 - bias_f;

    let q = if unb < min_normal_unb {
        // subnormal range: round to the uniform grid 2^(min_normal - m)
        // via the exact additive trick (pure f32 IEEE RNE arithmetic,
        // matching XLA's CPU semantics exactly)
        let t_plus_150 = (min_normal_unb - m as i32 + 150) as u32;
        let c = f32::from_bits((t_plus_150 << 23) | 0x0040_0000); // 1.5*2^(t+23)
        let absx = f32::from_bits(mag);
        ((absx + c) - c).to_bits()
    } else {
        // normal range: RNE at (23 - m) encoding bits
        let shift = 23 - m;
        if shift == 0 {
            mag
        } else {
            let half = 1u32 << (shift - 1);
            let lsb = (mag >> shift) & 1;
            ((mag.wrapping_add(half - 1 + lsb)) >> shift) << shift
        }
    };

    // saturate to max finite (also inf/NaN and RNE carry past the top)
    let max_bexp = (bias_f + 127) as u32;
    let frac = ((1u32 << m) - 1) << (23 - m);
    let max_mag = (max_bexp << 23) | frac;
    f32::from_bits(sign | q.min(max_mag))
}

fn quantize_scalar(xs: &[f32], e: u32, m: u32, out: &mut [f32]) {
    debug_assert_eq!(xs.len(), out.len());
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = quantize_one_em(x, e, m);
    }
}

fn quantize_in_place_scalar(xs: &mut [f32], e: u32, m: u32) {
    for x in xs.iter_mut() {
        *x = quantize_one_em(*x, e, m);
    }
}

fn axpb_scalar(s: f32, b: f32, xs: &[f32], out: &mut [f32]) {
    debug_assert_eq!(xs.len(), out.len());
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = s * x + b;
    }
}

fn axpb_in_place_scalar(s: f32, b: f32, xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = s * *x + b;
    }
}

fn fit_update_scalar(acc: &mut FitSums, v: &[f32], t: &[f32]) {
    debug_assert_eq!(v.len(), t.len());
    for (&a, &b) in v.iter().zip(t) {
        acc.push(a, b);
    }
}

fn xor_bytes_scalar(a: &[u8], b: &[u8], out: &mut [u8]) {
    debug_assert!(a.len() == b.len() && a.len() == out.len());
    let n = a.len();
    let mut i = 0usize;
    while i + 8 <= n {
        let x = u64::from_le_bytes(a[i..i + 8].try_into().unwrap())
            ^ u64::from_le_bytes(b[i..i + 8].try_into().unwrap());
        out[i..i + 8].copy_from_slice(&x.to_le_bytes());
        i += 8;
    }
    while i < n {
        out[i] = a[i] ^ b[i];
        i += 1;
    }
}

fn or_fold_scalar(bytes: &[u8]) -> u64 {
    let n = bytes.len();
    let mut acc = 0u64;
    let mut i = 0usize;
    while i + 8 <= n {
        acc |= u64::from_le_bytes(bytes[i..i + 8].try_into().unwrap());
        i += 8;
    }
    if i < n {
        let mut t = [0u8; 8];
        t[..n - i].copy_from_slice(&bytes[i..]);
        acc |= u64::from_le_bytes(t);
    }
    acc
}

static SCALAR: Kernels = Kernels {
    level: Level::Scalar,
    quantize: quantize_scalar,
    quantize_in_place: quantize_in_place_scalar,
    axpb: axpb_scalar,
    axpb_in_place: axpb_in_place_scalar,
    fit_update: fit_update_scalar,
    pack_pow2: None,
    unpack_pow2: None,
    xor_bytes: xor_bytes_scalar,
    or_fold: or_fold_scalar,
};

/// Bytewise `out = a ^ b` through the dispatched kernel table. The delta
/// stage's XOR pass — exact integer math, so every level produces the
/// identical bytes; parity is still property-tested like the f32 kernels.
pub fn xor_bytes(a: &[u8], b: &[u8], out: &mut [u8]) {
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len(), out.len());
    (kernels().xor_bytes)(a, b, out)
}

/// OR-fold of `bytes` viewed as little-endian u64 words (final partial
/// word zero-padded) through the dispatched kernel table. The delta
/// bitpacker derives each block's width class from this fold.
pub fn or_fold_words(bytes: &[u8]) -> u64 {
    (kernels().or_fold)(bytes)
}

// ---------------------------------------------------------------------------
// CRC32C (Castagnoli) — wire-integrity checksum
// ---------------------------------------------------------------------------

/// `fn(seed, bytes) -> crc` — incremental CRC32C over a byte slice.
pub type Crc32cFn = fn(u32, &[u8]) -> u32;

/// Reflected Castagnoli polynomial (the `crc32` instruction's polynomial).
const CRC32C_POLY: u32 = 0x82F6_3B78;

const fn crc32c_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { (c >> 1) ^ CRC32C_POLY } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC32C_TABLE: [u32; 256] = crc32c_table();

/// Portable byte-at-a-time CRC32C — the reference semantics. Unlike the
/// f32 kernels there is nothing to keep bit-exact by construction here:
/// CRC32C is exact integer math, so every dispatch path returns the
/// identical checksum and the wire bytes are ISA-independent for free.
fn crc32c_scalar(seed: u32, bytes: &[u8]) -> u32 {
    let mut c = !seed;
    for &b in bytes {
        c = CRC32C_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

#[cfg(target_arch = "x86_64")]
fn crc32c_hw(seed: u32, bytes: &[u8]) -> u32 {
    // Safety: only selected after `is_x86_feature_detected!("sse4.2")`.
    unsafe { crc32c_sse42(seed, bytes) }
}

/// Safety: caller proved SSE4.2 (the `crc32` instruction family).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse4.2")]
unsafe fn crc32c_sse42(seed: u32, bytes: &[u8]) -> u32 {
    use std::arch::x86_64::{_mm_crc32_u64, _mm_crc32_u8};
    let mut c = !seed as u64;
    let (chunks, tail) = bytes.split_at(bytes.len() & !7);
    for ch in chunks.chunks_exact(8) {
        let w = u64::from_le_bytes([
            ch[0], ch[1], ch[2], ch[3], ch[4], ch[5], ch[6], ch[7],
        ]);
        c = _mm_crc32_u64(c, w);
    }
    let mut c = c as u32;
    for &b in tail {
        c = _mm_crc32_u8(c, b);
    }
    !c
}

fn resolve_crc32c() -> Crc32cFn {
    if env_forces_scalar() {
        return crc32c_scalar;
    }
    #[cfg(target_arch = "x86_64")]
    if is_x86_feature_detected!("sse4.2") {
        return crc32c_hw;
    }
    crc32c_scalar
}

/// CRC32C (Castagnoli) of `bytes`, continuing from `seed` (pass 0 to
/// start a fresh checksum). Dispatches once per process to the SSE4.2
/// `crc32` instruction when available — its own feature gate, independent
/// of the f32 kernel table (SSE4.2 is neither implied by SSE2 nor
/// required for AVX2 dispatch). `OMC_FORCE_SCALAR=1` and a
/// [`force_level`]`(Some(Level::Scalar))` override both pin the table
/// path, so the CRC bench rows can compare implementations from one
/// process. Every path computes the identical checksum.
pub fn crc32c(seed: u32, bytes: &[u8]) -> u32 {
    static RESOLVED: OnceLock<Crc32cFn> = OnceLock::new();
    if OVERRIDE.load(Ordering::Relaxed) == 1 {
        return crc32c_scalar(seed, bytes);
    }
    (RESOLVED.get_or_init(resolve_crc32c))(seed, bytes)
}

/// The scalar CRC32C reference, exported for bench comparison rows.
pub fn crc32c_reference(seed: u32, bytes: &[u8]) -> u32 {
    crc32c_scalar(seed, bytes)
}

// ---------------------------------------------------------------------------
// virtual-lane least-squares sums
// ---------------------------------------------------------------------------

/// Virtual lane width of [`FitSums`]: 4 f64 lanes (one AVX2 `ymm`; two
/// SSE2 `xmm`; a 4-element array in scalar code). Fixed so the
/// accumulation schedule — and therefore every bit of the result — is
/// identical on every ISA path.
pub const FIT_LANES: usize = 4;

/// Lane-split f64 sums for the PVT least-squares fit. Element `i` of the
/// stream always lands in lane `i % FIT_LANES`; [`FitSums::totals`]
/// folds the lanes in a fixed pairwise order. `omc::transform::FitAcc`
/// wraps this with the closed-form solve.
#[derive(Clone, Copy, Debug, Default)]
pub struct FitSums {
    n: usize,
    v: [f64; FIT_LANES],
    t: [f64; FIT_LANES],
    tt: [f64; FIT_LANES],
    vt: [f64; FIT_LANES],
}

impl FitSums {
    /// Empty sums (zero pairs seen).
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of pairs accumulated so far.
    pub fn len(&self) -> usize {
        self.n
    }

    /// Accumulate one `(original, quantized)` pair into lane
    /// `len() % FIT_LANES` — the scalar reference schedule.
    #[inline]
    pub fn push(&mut self, v: f32, t: f32) {
        let lane = self.n % FIT_LANES;
        let a = v as f64;
        let b = t as f64;
        self.v[lane] += a;
        self.t[lane] += b;
        self.tt[lane] += b * b;
        self.vt[lane] += a * b;
        self.n += 1;
    }

    /// Accumulate a batch through the dispatched kernel (identical lane
    /// schedule as element-by-element [`FitSums::push`]).
    pub fn update(&mut self, v: &[f32], t: &[f32]) {
        assert_eq!(v.len(), t.len());
        (kernels().fit_update)(self, v, t);
    }

    /// Folded totals `(n, Σv, Σt, Σt², Σvt)`. The fold order is fixed —
    /// `(l0 + l1) + (l2 + l3)` per sum — so the totals are a pure
    /// function of the input stream, never of the ISA path.
    pub fn totals(&self) -> (usize, f64, f64, f64, f64) {
        let fold = |s: &[f64; FIT_LANES]| (s[0] + s[1]) + (s[2] + s[3]);
        (self.n, fold(&self.v), fold(&self.t), fold(&self.tt), fold(&self.vt))
    }
}

// ---------------------------------------------------------------------------
// x86_64 vector kernels
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! SSE2 + AVX2 implementations. Safety pattern: the `unsafe`
    //! target-feature inner functions are only reachable through the
    //! tables below, and the AVX2 table is only handed out after
    //! `is_x86_feature_detected!("avx2")` succeeded (SSE2 is part of the
    //! x86_64 base ISA, so its intrinsics are always safe to issue).

    use std::arch::x86_64::*;

    use super::{
        or_fold_scalar, quantize_in_place_scalar, quantize_one_em,
        quantize_scalar, xor_bytes_scalar, FitSums, Kernels, Level, FIT_LANES,
    };

    pub(super) static SSE2: Kernels = Kernels {
        level: Level::Sse2,
        quantize: quantize_sse2,
        quantize_in_place: quantize_in_place_sse2,
        axpb: axpb_sse2,
        axpb_in_place: axpb_in_place_sse2,
        fit_update: fit_update_sse2,
        pack_pow2: None,
        unpack_pow2: None,
        xor_bytes: xor_bytes_sse2,
        or_fold: or_fold_sse2,
    };

    pub(super) static AVX2: Kernels = Kernels {
        level: Level::Avx2,
        quantize: quantize_avx2,
        quantize_in_place: quantize_in_place_avx2,
        axpb: axpb_avx2,
        axpb_in_place: axpb_in_place_avx2,
        fit_update: fit_update_avx2,
        pack_pow2: Some(pack_pow2_avx2),
        unpack_pow2: Some(unpack_pow2_avx2),
        xor_bytes: xor_bytes_avx2,
        or_fold: or_fold_avx2,
    };

    // -- sse2 helpers (emulating the SSE4.1/AVX2-only lane ops) ------------

    /// `mask ? b : a` with full-lane masks.
    #[inline(always)]
    unsafe fn blend_sse2(a: __m128i, b: __m128i, mask: __m128i) -> __m128i {
        _mm_or_si128(_mm_and_si128(mask, b), _mm_andnot_si128(mask, a))
    }

    /// Lanewise signed 32-bit max (SSE4.1's `pmaxsd`, emulated).
    #[inline(always)]
    unsafe fn max_epi32_sse2(a: __m128i, b: __m128i) -> __m128i {
        let gt = _mm_cmpgt_epi32(a, b);
        blend_sse2(b, a, gt)
    }

    /// Lanewise unsigned 32-bit min via the sign-bias trick (the rounded
    /// magnitude can exceed `i32::MAX` for NaN-payload inputs, so the
    /// compare must be unsigned, exactly like the scalar `u32::min`).
    #[inline(always)]
    unsafe fn min_epu32_sse2(a: __m128i, b: __m128i) -> __m128i {
        let bias = _mm_set1_epi32(i32::MIN);
        let gt = _mm_cmpgt_epi32(_mm_xor_si128(a, bias), _mm_xor_si128(b, bias));
        blend_sse2(a, b, gt)
    }

    // -- quantize ----------------------------------------------------------

    fn quantize_sse2(xs: &[f32], e: u32, m: u32, out: &mut [f32]) {
        debug_assert_eq!(xs.len(), out.len());
        if m >= 23 {
            // no vector path for full-width mantissas (`shift == 0`);
            // delegate so every level stays bit-exact, including the
            // scalar path's non-finite saturation
            return quantize_scalar(xs, e, m, out);
        }
        unsafe { quantize_sse2_raw(xs.as_ptr(), out.as_mut_ptr(), xs.len(), e, m) }
    }

    fn quantize_in_place_sse2(xs: &mut [f32], e: u32, m: u32) {
        if m >= 23 {
            return quantize_in_place_scalar(xs, e, m);
        }
        // both pointers from one as_mut_ptr: a later shared-derived src
        // would be invalidated by the mutable reborrow (aliasing-model UB)
        let p = xs.as_mut_ptr();
        unsafe { quantize_sse2_raw(p, p, xs.len(), e, m) }
    }

    /// Safety: SSE2 is part of the x86_64 base ISA; `src`/`dst` must each
    /// be valid for `n` f32 reads/writes (they may alias exactly).
    unsafe fn quantize_sse2_raw(src: *const f32, dst: *mut f32, n: usize, e: u32, m: u32) {
        let shift = 23 - m;
        let bias_f = (1i32 << (e - 1)) - 1;
        let min_normal_unb = 1 - bias_f;
        let t_plus_150 = (min_normal_unb - m as i32 + 150) as u32;
        let max_bexp = (bias_f + 127) as u32;
        let max_mag = (max_bexp << 23) | (((1u32 << m) - 1) << shift);

        let vsign = _mm_set1_epi32(0x8000_0000u32 as i32);
        let vmagm = _mm_set1_epi32(0x7FFF_FFFF);
        let vone = _mm_set1_epi32(1);
        let v127 = _mm_set1_epi32(127);
        let vmn = _mm_set1_epi32(min_normal_unb);
        let vc = _mm_set1_ps(f32::from_bits((t_plus_150 << 23) | 0x0040_0000));
        let vhalf = _mm_set1_epi32((1i32 << (shift - 1)) - 1);
        let vmax = _mm_set1_epi32(max_mag as i32);
        let csh = _mm_cvtsi32_si128(shift as i32);
        let c23 = _mm_cvtsi32_si128(23);

        let mut i = 0usize;
        while i + 4 <= n {
            let u = _mm_loadu_si128(src.add(i) as *const __m128i);
            let sign = _mm_and_si128(u, vsign);
            let mag = _mm_and_si128(u, vmagm);
            let bexp = _mm_srl_epi32(mag, c23);
            let unb = _mm_sub_epi32(max_epi32_sse2(bexp, vone), v127);
            let absx = _mm_castsi128_ps(mag);
            let qsub = _mm_castps_si128(_mm_sub_ps(_mm_add_ps(absx, vc), vc));
            let lsb = _mm_and_si128(_mm_srl_epi32(mag, csh), vone);
            let bump = _mm_add_epi32(_mm_add_epi32(mag, vhalf), lsb);
            let qnorm = _mm_sll_epi32(_mm_srl_epi32(bump, csh), csh);
            let is_sub = _mm_cmpgt_epi32(vmn, unb);
            let q = min_epu32_sse2(blend_sse2(qnorm, qsub, is_sub), vmax);
            _mm_storeu_si128(dst.add(i) as *mut __m128i, _mm_or_si128(sign, q));
            i += 4;
        }
        while i < n {
            *dst.add(i) = quantize_one_em(*src.add(i), e, m);
            i += 1;
        }
    }

    fn quantize_avx2(xs: &[f32], e: u32, m: u32, out: &mut [f32]) {
        debug_assert_eq!(xs.len(), out.len());
        if m >= 23 {
            return quantize_scalar(xs, e, m, out);
        }
        unsafe { quantize_avx2_raw(xs.as_ptr(), out.as_mut_ptr(), xs.len(), e, m) }
    }

    fn quantize_in_place_avx2(xs: &mut [f32], e: u32, m: u32) {
        if m >= 23 {
            return quantize_in_place_scalar(xs, e, m);
        }
        let p = xs.as_mut_ptr();
        unsafe { quantize_avx2_raw(p, p, xs.len(), e, m) }
    }

    /// Safety: caller proved AVX2 (table gating); `src`/`dst` must each
    /// be valid for `n` f32 reads/writes (they may alias exactly).
    #[target_feature(enable = "avx2")]
    unsafe fn quantize_avx2_raw(src: *const f32, dst: *mut f32, n: usize, e: u32, m: u32) {
        let shift = 23 - m;
        let bias_f = (1i32 << (e - 1)) - 1;
        let min_normal_unb = 1 - bias_f;
        let t_plus_150 = (min_normal_unb - m as i32 + 150) as u32;
        let max_bexp = (bias_f + 127) as u32;
        let max_mag = (max_bexp << 23) | (((1u32 << m) - 1) << shift);

        let vsign = _mm256_set1_epi32(0x8000_0000u32 as i32);
        let vmagm = _mm256_set1_epi32(0x7FFF_FFFF);
        let vone = _mm256_set1_epi32(1);
        let v127 = _mm256_set1_epi32(127);
        let vmn = _mm256_set1_epi32(min_normal_unb);
        let vc = _mm256_set1_ps(f32::from_bits((t_plus_150 << 23) | 0x0040_0000));
        let vhalf = _mm256_set1_epi32((1i32 << (shift - 1)) - 1);
        let vmax = _mm256_set1_epi32(max_mag as i32);
        let csh = _mm_cvtsi32_si128(shift as i32);
        let c23 = _mm_cvtsi32_si128(23);

        let mut i = 0usize;
        while i + 8 <= n {
            let u = _mm256_loadu_si256(src.add(i) as *const __m256i);
            let sign = _mm256_and_si256(u, vsign);
            let mag = _mm256_and_si256(u, vmagm);
            let bexp = _mm256_srl_epi32(mag, c23);
            let unb = _mm256_sub_epi32(_mm256_max_epi32(bexp, vone), v127);
            let absx = _mm256_castsi256_ps(mag);
            let qsub = _mm256_castps_si256(_mm256_sub_ps(_mm256_add_ps(absx, vc), vc));
            let lsb = _mm256_and_si256(_mm256_srl_epi32(mag, csh), vone);
            let bump = _mm256_add_epi32(_mm256_add_epi32(mag, vhalf), lsb);
            let qnorm = _mm256_sll_epi32(_mm256_srl_epi32(bump, csh), csh);
            let is_sub = _mm256_cmpgt_epi32(vmn, unb);
            let q = _mm256_min_epu32(_mm256_blendv_epi8(qnorm, qsub, is_sub), vmax);
            _mm256_storeu_si256(dst.add(i) as *mut __m256i, _mm256_or_si256(sign, q));
            i += 8;
        }
        while i < n {
            *dst.add(i) = quantize_one_em(*src.add(i), e, m);
            i += 1;
        }
    }

    // -- affine ------------------------------------------------------------

    fn axpb_sse2(s: f32, b: f32, xs: &[f32], out: &mut [f32]) {
        debug_assert_eq!(xs.len(), out.len());
        unsafe {
            let vs = _mm_set1_ps(s);
            let vb = _mm_set1_ps(b);
            let n = xs.len();
            let mut i = 0usize;
            while i + 4 <= n {
                let x = _mm_loadu_ps(xs.as_ptr().add(i));
                let y = _mm_add_ps(_mm_mul_ps(x, vs), vb);
                _mm_storeu_ps(out.as_mut_ptr().add(i), y);
                i += 4;
            }
            while i < n {
                *out.get_unchecked_mut(i) = s * *xs.get_unchecked(i) + b;
                i += 1;
            }
        }
    }

    fn axpb_in_place_sse2(s: f32, b: f32, xs: &mut [f32]) {
        unsafe {
            let vs = _mm_set1_ps(s);
            let vb = _mm_set1_ps(b);
            let n = xs.len();
            let p = xs.as_mut_ptr();
            let mut i = 0usize;
            while i + 4 <= n {
                let x = _mm_loadu_ps(p.add(i));
                _mm_storeu_ps(p.add(i), _mm_add_ps(_mm_mul_ps(x, vs), vb));
                i += 4;
            }
            while i < n {
                *p.add(i) = s * *p.add(i) + b;
                i += 1;
            }
        }
    }

    fn axpb_avx2(s: f32, b: f32, xs: &[f32], out: &mut [f32]) {
        debug_assert_eq!(xs.len(), out.len());
        unsafe { axpb_avx2_raw(s, b, xs.as_ptr(), out.as_mut_ptr(), xs.len()) }
    }

    fn axpb_in_place_avx2(s: f32, b: f32, xs: &mut [f32]) {
        let p = xs.as_mut_ptr();
        unsafe { axpb_avx2_raw(s, b, p, p, xs.len()) }
    }

    /// Safety: caller proved AVX2; `src`/`dst` valid for `n` f32s (may
    /// alias exactly). Mul-then-add per lane — never FMA-fused, matching
    /// scalar `s * x + b` rounding.
    #[target_feature(enable = "avx2")]
    unsafe fn axpb_avx2_raw(s: f32, b: f32, src: *const f32, dst: *mut f32, n: usize) {
        let vs = _mm256_set1_ps(s);
        let vb = _mm256_set1_ps(b);
        let mut i = 0usize;
        while i + 8 <= n {
            let x = _mm256_loadu_ps(src.add(i));
            _mm256_storeu_ps(dst.add(i), _mm256_add_ps(_mm256_mul_ps(x, vs), vb));
            i += 8;
        }
        while i < n {
            *dst.add(i) = s * *src.add(i) + b;
            i += 1;
        }
    }

    // -- fit update --------------------------------------------------------

    fn fit_update_sse2(acc: &mut FitSums, v: &[f32], t: &[f32]) {
        debug_assert_eq!(v.len(), t.len());
        let n = v.len();
        let mut i = 0usize;
        while acc.n % FIT_LANES != 0 && i < n {
            acc.push(v[i], t[i]);
            i += 1;
        }
        let vec_n = (n - i) / FIT_LANES * FIT_LANES;
        if vec_n > 0 {
            unsafe {
                // two f64 lane pairs per sum: lanes {0,1} and {2,3}
                let mut sv0 = _mm_loadu_pd(acc.v.as_ptr());
                let mut sv1 = _mm_loadu_pd(acc.v.as_ptr().add(2));
                let mut st0 = _mm_loadu_pd(acc.t.as_ptr());
                let mut st1 = _mm_loadu_pd(acc.t.as_ptr().add(2));
                let mut stt0 = _mm_loadu_pd(acc.tt.as_ptr());
                let mut stt1 = _mm_loadu_pd(acc.tt.as_ptr().add(2));
                let mut svt0 = _mm_loadu_pd(acc.vt.as_ptr());
                let mut svt1 = _mm_loadu_pd(acc.vt.as_ptr().add(2));
                let mut k = i;
                let end = i + vec_n;
                while k < end {
                    // 8-byte loads: 2 f32 -> 2 f64, no over-read at the tail
                    let a0 = _mm_cvtps_pd(_mm_castsi128_ps(_mm_loadl_epi64(
                        v.as_ptr().add(k) as *const __m128i,
                    )));
                    let a1 = _mm_cvtps_pd(_mm_castsi128_ps(_mm_loadl_epi64(
                        v.as_ptr().add(k + 2) as *const __m128i,
                    )));
                    let b0 = _mm_cvtps_pd(_mm_castsi128_ps(_mm_loadl_epi64(
                        t.as_ptr().add(k) as *const __m128i,
                    )));
                    let b1 = _mm_cvtps_pd(_mm_castsi128_ps(_mm_loadl_epi64(
                        t.as_ptr().add(k + 2) as *const __m128i,
                    )));
                    sv0 = _mm_add_pd(sv0, a0);
                    sv1 = _mm_add_pd(sv1, a1);
                    st0 = _mm_add_pd(st0, b0);
                    st1 = _mm_add_pd(st1, b1);
                    stt0 = _mm_add_pd(stt0, _mm_mul_pd(b0, b0));
                    stt1 = _mm_add_pd(stt1, _mm_mul_pd(b1, b1));
                    svt0 = _mm_add_pd(svt0, _mm_mul_pd(a0, b0));
                    svt1 = _mm_add_pd(svt1, _mm_mul_pd(a1, b1));
                    k += 4;
                }
                _mm_storeu_pd(acc.v.as_mut_ptr(), sv0);
                _mm_storeu_pd(acc.v.as_mut_ptr().add(2), sv1);
                _mm_storeu_pd(acc.t.as_mut_ptr(), st0);
                _mm_storeu_pd(acc.t.as_mut_ptr().add(2), st1);
                _mm_storeu_pd(acc.tt.as_mut_ptr(), stt0);
                _mm_storeu_pd(acc.tt.as_mut_ptr().add(2), stt1);
                _mm_storeu_pd(acc.vt.as_mut_ptr(), svt0);
                _mm_storeu_pd(acc.vt.as_mut_ptr().add(2), svt1);
            }
            acc.n += vec_n;
            i += vec_n;
        }
        while i < n {
            acc.push(v[i], t[i]);
            i += 1;
        }
    }

    fn fit_update_avx2(acc: &mut FitSums, v: &[f32], t: &[f32]) {
        debug_assert_eq!(v.len(), t.len());
        unsafe { fit_update_avx2_inner(acc, v, t) }
    }

    /// Safety: caller proved AVX2 (table gating).
    #[target_feature(enable = "avx2")]
    unsafe fn fit_update_avx2_inner(acc: &mut FitSums, v: &[f32], t: &[f32]) {
        let n = v.len();
        let mut i = 0usize;
        while acc.n % FIT_LANES != 0 && i < n {
            acc.push(v[i], t[i]);
            i += 1;
        }
        let vec_n = (n - i) / FIT_LANES * FIT_LANES;
        if vec_n > 0 {
            let mut sv = _mm256_loadu_pd(acc.v.as_ptr());
            let mut st = _mm256_loadu_pd(acc.t.as_ptr());
            let mut stt = _mm256_loadu_pd(acc.tt.as_ptr());
            let mut svt = _mm256_loadu_pd(acc.vt.as_ptr());
            let mut k = i;
            let end = i + vec_n;
            while k < end {
                let a = _mm256_cvtps_pd(_mm_loadu_ps(v.as_ptr().add(k)));
                let b = _mm256_cvtps_pd(_mm_loadu_ps(t.as_ptr().add(k)));
                sv = _mm256_add_pd(sv, a);
                st = _mm256_add_pd(st, b);
                stt = _mm256_add_pd(stt, _mm256_mul_pd(b, b));
                svt = _mm256_add_pd(svt, _mm256_mul_pd(a, b));
                k += 4;
            }
            _mm256_storeu_pd(acc.v.as_mut_ptr(), sv);
            _mm256_storeu_pd(acc.t.as_mut_ptr(), st);
            _mm256_storeu_pd(acc.tt.as_mut_ptr(), stt);
            _mm256_storeu_pd(acc.vt.as_mut_ptr(), svt);
            acc.n += vec_n;
            i += vec_n;
        }
        while i < n {
            acc.push(v[i], t[i]);
            i += 1;
        }
    }

    // -- delta byte kernels --------------------------------------------------

    fn xor_bytes_sse2(a: &[u8], b: &[u8], out: &mut [u8]) {
        debug_assert!(a.len() == b.len() && a.len() == out.len());
        let n = a.len();
        let mut i = 0usize;
        unsafe {
            while i + 16 <= n {
                let x = _mm_loadu_si128(a.as_ptr().add(i) as *const __m128i);
                let y = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
                _mm_storeu_si128(
                    out.as_mut_ptr().add(i) as *mut __m128i,
                    _mm_xor_si128(x, y),
                );
                i += 16;
            }
        }
        xor_bytes_scalar(&a[i..], &b[i..], &mut out[i..]);
    }

    fn or_fold_sse2(bytes: &[u8]) -> u64 {
        let n = bytes.len();
        let mut i = 0usize;
        let mut acc;
        unsafe {
            let mut v = _mm_setzero_si128();
            while i + 16 <= n {
                v = _mm_or_si128(
                    v,
                    _mm_loadu_si128(bytes.as_ptr().add(i) as *const __m128i),
                );
                i += 16;
            }
            let hi = _mm_unpackhi_epi64(v, v);
            acc = _mm_cvtsi128_si64(_mm_or_si128(v, hi)) as u64;
        }
        acc |= or_fold_scalar(&bytes[i..]);
        acc
    }

    fn xor_bytes_avx2(a: &[u8], b: &[u8], out: &mut [u8]) {
        debug_assert!(a.len() == b.len() && a.len() == out.len());
        unsafe { xor_bytes_avx2_inner(a, b, out) }
    }

    /// Safety: caller proved AVX2 (table gating).
    #[target_feature(enable = "avx2")]
    unsafe fn xor_bytes_avx2_inner(a: &[u8], b: &[u8], out: &mut [u8]) {
        let n = a.len();
        let mut i = 0usize;
        while i + 32 <= n {
            let x = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
            let y = _mm256_loadu_si256(b.as_ptr().add(i) as *const __m256i);
            _mm256_storeu_si256(
                out.as_mut_ptr().add(i) as *mut __m256i,
                _mm256_xor_si256(x, y),
            );
            i += 32;
        }
        xor_bytes_sse2(&a[i..], &b[i..], &mut out[i..]);
    }

    fn or_fold_avx2(bytes: &[u8]) -> u64 {
        unsafe { or_fold_avx2_inner(bytes) }
    }

    /// Safety: caller proved AVX2 (table gating).
    #[target_feature(enable = "avx2")]
    unsafe fn or_fold_avx2_inner(bytes: &[u8]) -> u64 {
        let n = bytes.len();
        let mut i = 0usize;
        let mut v = _mm256_setzero_si256();
        while i + 32 <= n {
            v = _mm256_or_si256(
                v,
                _mm256_loadu_si256(bytes.as_ptr().add(i) as *const __m256i),
            );
            i += 32;
        }
        let folded = _mm_or_si128(
            _mm256_castsi256_si128(v),
            _mm256_extracti128_si256::<1>(v),
        );
        let hi = _mm_unpackhi_epi64(folded, folded);
        let acc = _mm_cvtsi128_si64(_mm_or_si128(folded, hi)) as u64;
        acc | or_fold_sse2(&bytes[i..])
    }

    // -- pow2-width block encode/decode -------------------------------------

    /// Broadcast constants for the lanewise `SxEyMz` encoder. Only valid
    /// for `e` in `2..8` (the dispatcher guarantees it): then no
    /// representable value is an f32 subnormal, `1/quantum` is a normal
    /// f32, and every target-subnormal value — including the saturation
    /// value — lies exactly on the `quantum` grid, so the subnormal
    /// integer `k` is exactly `|x| * (1/quantum)` (an exact product,
    /// converted by `cvtps` on an exact integer). `e = 1` breaks the
    /// grid-alignment premise: its saturation value `2 − 2^−m` is not a
    /// quantum multiple, so those formats stay on the word kernels.
    struct EncConsts {
        vsignm: __m256i,
        vmagm: __m256i,
        vfracm: __m256i,
        v127: __m256i,
        vbias: __m256i,
        vmn: __m256i,
        vinvq: __m256,
        c_sign: __m128i,
        c_mant: __m128i,
        c_m: __m128i,
        c23: __m128i,
    }

    #[inline(always)]
    unsafe fn enc_consts(e: u32, m: u32) -> EncConsts {
        let bias_f = (1i32 << (e - 1)) - 1;
        let min_normal_unb = 1 - bias_f;
        // 2^(m - min_normal) = 1/quantum; exponent m + bias - 1 <= 127
        // for every e < 8 format of width 8 or 16
        let invq_bits = ((m as i32 + bias_f - 1 + 127) as u32) << 23;
        EncConsts {
            vsignm: _mm256_set1_epi32(0x8000_0000u32 as i32),
            vmagm: _mm256_set1_epi32(0x7FFF_FFFF),
            vfracm: _mm256_set1_epi32(0x007F_FFFF),
            v127: _mm256_set1_epi32(127),
            vbias: _mm256_set1_epi32(bias_f),
            vmn: _mm256_set1_epi32(min_normal_unb),
            vinvq: _mm256_set1_ps(f32::from_bits(invq_bits)),
            c_sign: _mm_cvtsi32_si128((31 - (e + m)) as i32),
            c_mant: _mm_cvtsi32_si128((23 - m) as i32),
            c_m: _mm_cvtsi32_si128(m as i32),
            c23: _mm_cvtsi32_si128(23),
        }
    }

    /// Encode 8 representable f32s to their `(1+e+m)`-bit codes.
    #[inline(always)]
    unsafe fn encode8_avx2(u: __m256i, c: &EncConsts) -> __m256i {
        let sign_c = _mm256_srl_epi32(_mm256_and_si256(u, c.vsignm), c.c_sign);
        let mag = _mm256_and_si256(u, c.vmagm);
        let bexp = _mm256_srl_epi32(mag, c.c23);
        let unb = _mm256_sub_epi32(bexp, c.v127);
        // normal in the target: field = unb + bias, mantissa = top m bits
        let field = _mm256_add_epi32(unb, c.vbias);
        let mant = _mm256_srl_epi32(_mm256_and_si256(mag, c.vfracm), c.c_mant);
        let code_n = _mm256_or_si256(_mm256_sll_epi32(field, c.c_m), mant);
        // subnormal (or zero): k = |x| / quantum, an exact small integer
        let absx = _mm256_castsi256_ps(mag);
        let k = _mm256_cvtps_epi32(_mm256_mul_ps(absx, c.vinvq));
        let is_sub = _mm256_cmpgt_epi32(c.vmn, unb);
        _mm256_or_si256(sign_c, _mm256_blendv_epi8(code_n, k, is_sub))
    }

    fn pack_pow2_avx2(values: &[f32], e: u32, m: u32, out: &mut [u8]) {
        debug_assert!((2..8).contains(&e) && (e + m == 7 || e + m == 15));
        debug_assert_eq!(values.len() % 256, 0);
        debug_assert_eq!(out.len(), values.len() * (1 + e + m) as usize / 8);
        unsafe {
            if e + m == 15 {
                pack16_avx2(values, e, m, out)
            } else {
                pack8_avx2(values, e, m, out)
            }
        }
    }

    /// Safety: caller proved AVX2; slices sized per `pack_pow2_avx2`.
    #[target_feature(enable = "avx2")]
    unsafe fn pack16_avx2(values: &[f32], e: u32, m: u32, out: &mut [u8]) {
        let c = enc_consts(e, m);
        let mut src = values.as_ptr();
        let mut dst = out.as_mut_ptr();
        for _ in 0..values.len() / 16 {
            let a = encode8_avx2(_mm256_loadu_si256(src as *const __m256i), &c);
            let b = encode8_avx2(_mm256_loadu_si256(src.add(8) as *const __m256i), &c);
            // packus interleaves 128-bit halves: fix with a qword permute
            let p = _mm256_packus_epi32(a, b);
            let fixed = _mm256_permute4x64_epi64::<0b11011000>(p);
            _mm256_storeu_si256(dst as *mut __m256i, fixed);
            src = src.add(16);
            dst = dst.add(32);
        }
    }

    /// Safety: caller proved AVX2; slices sized per `pack_pow2_avx2`.
    #[target_feature(enable = "avx2")]
    unsafe fn pack8_avx2(values: &[f32], e: u32, m: u32, out: &mut [u8]) {
        let c = enc_consts(e, m);
        // the two packus stages leave the 32 bytes in dword groups
        // [a0 b0 c0 d0 a1 b1 c1 d1]; this permutation restores stream order
        let idx = _mm256_setr_epi32(0, 4, 1, 5, 2, 6, 3, 7);
        let mut src = values.as_ptr();
        let mut dst = out.as_mut_ptr();
        for _ in 0..values.len() / 32 {
            let a = encode8_avx2(_mm256_loadu_si256(src as *const __m256i), &c);
            let b = encode8_avx2(_mm256_loadu_si256(src.add(8) as *const __m256i), &c);
            let cc = encode8_avx2(_mm256_loadu_si256(src.add(16) as *const __m256i), &c);
            let d = encode8_avx2(_mm256_loadu_si256(src.add(24) as *const __m256i), &c);
            let p = _mm256_packus_epi16(_mm256_packus_epi32(a, b), _mm256_packus_epi32(cc, d));
            let fixed = _mm256_permutevar8x32_epi32(p, idx);
            _mm256_storeu_si256(dst as *mut __m256i, fixed);
            src = src.add(32);
            dst = dst.add(32);
        }
    }

    /// Broadcast constants for the lanewise decoder.
    struct DecConsts {
        vem: __m256i,
        vmm: __m256i,
        vzero: __m256i,
        vrebias: __m256i,
        vq: __m256,
        c_m: __m128i,
        c_em: __m128i,
        c_shift: __m128i,
        c23: __m128i,
        c31: __m128i,
    }

    #[inline(always)]
    unsafe fn dec_consts(e: u32, m: u32, quantum: f32) -> DecConsts {
        let bias_f = (1i32 << (e - 1)) - 1;
        DecConsts {
            vem: _mm256_set1_epi32(((1u32 << e) - 1) as i32),
            vmm: _mm256_set1_epi32(((1u32 << m) - 1) as i32),
            vzero: _mm256_setzero_si256(),
            vrebias: _mm256_set1_epi32(127 - bias_f),
            vq: _mm256_set1_ps(quantum),
            c_m: _mm_cvtsi32_si128(m as i32),
            c_em: _mm_cvtsi32_si128((e + m) as i32),
            c_shift: _mm_cvtsi32_si128((23 - m) as i32),
            c23: _mm_cvtsi32_si128(23),
            c31: _mm_cvtsi32_si128(31),
        }
    }

    /// Decode 8 codes back to the exact f32 values.
    #[inline(always)]
    unsafe fn decode8_avx2(code: __m256i, c: &DecConsts) -> __m256 {
        let field = _mm256_and_si256(_mm256_srl_epi32(code, c.c_m), c.vem);
        let mant = _mm256_and_si256(code, c.vmm);
        let signb = _mm256_sll_epi32(_mm256_srl_epi32(code, c.c_em), c.c31);
        // zero/subnormal: mant * quantum, an exact product
        let sub = _mm256_castps_si256(_mm256_mul_ps(_mm256_cvtepi32_ps(mant), c.vq));
        // normal: rebuild the f32 encoding directly
        let bexp = _mm256_add_epi32(field, c.vrebias);
        let norm = _mm256_or_si256(
            _mm256_sll_epi32(bexp, c.c23),
            _mm256_sll_epi32(mant, c.c_shift),
        );
        let is_sub = _mm256_cmpeq_epi32(field, c.vzero);
        let bits = _mm256_or_si256(signb, _mm256_blendv_epi8(norm, sub, is_sub));
        _mm256_castsi256_ps(bits)
    }

    fn unpack_pow2_avx2(
        bytes: &[u8],
        e: u32,
        m: u32,
        quantum: f32,
        map: Option<(f32, f32)>,
        out: &mut [f32],
    ) {
        debug_assert!((2..8).contains(&e) && (e + m == 7 || e + m == 15));
        debug_assert_eq!(out.len() % 256, 0);
        debug_assert_eq!(bytes.len(), out.len() * (1 + e + m) as usize / 8);
        unsafe {
            if e + m == 15 {
                unpack16_avx2(bytes, e, m, quantum, map, out)
            } else {
                unpack8_avx2(bytes, e, m, quantum, map, out)
            }
        }
    }

    /// Safety: caller proved AVX2; slices sized per `unpack_pow2_avx2`.
    #[target_feature(enable = "avx2")]
    unsafe fn unpack16_avx2(
        bytes: &[u8],
        e: u32,
        m: u32,
        quantum: f32,
        map: Option<(f32, f32)>,
        out: &mut [f32],
    ) {
        let c = dec_consts(e, m, quantum);
        let (vs, vb) = match map {
            Some((s, b)) => (_mm256_set1_ps(s), _mm256_set1_ps(b)),
            None => (_mm256_setzero_ps(), _mm256_setzero_ps()),
        };
        let mut src = bytes.as_ptr();
        let mut dst = out.as_mut_ptr();
        for _ in 0..out.len() / 16 {
            let raw = _mm256_loadu_si256(src as *const __m256i);
            let lo = _mm256_cvtepu16_epi32(_mm256_castsi256_si128(raw));
            let hi = _mm256_cvtepu16_epi32(_mm256_extracti128_si256::<1>(raw));
            let mut f0 = decode8_avx2(lo, &c);
            let mut f1 = decode8_avx2(hi, &c);
            if map.is_some() {
                f0 = _mm256_add_ps(_mm256_mul_ps(f0, vs), vb);
                f1 = _mm256_add_ps(_mm256_mul_ps(f1, vs), vb);
            }
            _mm256_storeu_ps(dst, f0);
            _mm256_storeu_ps(dst.add(8), f1);
            src = src.add(32);
            dst = dst.add(16);
        }
    }

    /// Safety: caller proved AVX2; slices sized per `unpack_pow2_avx2`.
    #[target_feature(enable = "avx2")]
    unsafe fn unpack8_avx2(
        bytes: &[u8],
        e: u32,
        m: u32,
        quantum: f32,
        map: Option<(f32, f32)>,
        out: &mut [f32],
    ) {
        let c = dec_consts(e, m, quantum);
        let (vs, vb) = match map {
            Some((s, b)) => (_mm256_set1_ps(s), _mm256_set1_ps(b)),
            None => (_mm256_setzero_ps(), _mm256_setzero_ps()),
        };
        let mut src = bytes.as_ptr();
        let mut dst = out.as_mut_ptr();
        for _ in 0..out.len() / 8 {
            let codes = _mm256_cvtepu8_epi32(_mm_loadl_epi64(src as *const __m128i));
            let mut f = decode8_avx2(codes, &c);
            if map.is_some() {
                f = _mm256_add_ps(_mm256_mul_ps(f, vs), vb);
            }
            _mm256_storeu_ps(dst, f);
            src = src.add(8);
            dst = dst.add(8);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Gen;

    fn edge_values(g: &mut Gen, n: usize) -> Vec<f32> {
        g.vec_edge_heavy(n)
    }

    #[test]
    fn scalar_level_always_available() {
        let levels = available_levels();
        assert!(levels.contains(&Level::Scalar));
        assert_eq!(kernels_for(Level::Scalar).unwrap().level, Level::Scalar);
        // the resolved table is one of the available levels
        assert!(levels.contains(&kernels().level));
    }

    #[test]
    fn quantize_levels_match_scalar_bitwise() {
        let mut g = Gen::new(31);
        for level in available_levels() {
            let k = kernels_for(level).unwrap();
            // (8, 23) locks the full-width-mantissa delegation: every
            // level must saturate non-finite inputs like the scalar path
            for (e, m) in [(5, 10), (4, 14), (3, 7), (2, 3), (4, 3), (5, 2), (8, 23)] {
                for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 100, 257] {
                    let xs = edge_values(&mut g, n);
                    let mut a = vec![0.0f32; n];
                    let mut b = vec![0.0f32; n];
                    quantize_scalar(&xs, e, m, &mut a);
                    (k.quantize)(&xs, e, m, &mut b);
                    let mut c = xs.clone();
                    (k.quantize_in_place)(&mut c, e, m);
                    for i in 0..n {
                        assert_eq!(
                            a[i].to_bits(),
                            b[i].to_bits(),
                            "{level:?} S1E{e}M{m} n={n} idx {i}"
                        );
                        assert_eq!(a[i].to_bits(), c[i].to_bits());
                    }
                }
            }
        }
    }

    #[test]
    fn axpb_levels_match_scalar_bitwise() {
        let mut g = Gen::new(33);
        for level in available_levels() {
            let k = kernels_for(level).unwrap();
            for n in [0usize, 1, 5, 8, 13, 64, 129] {
                let xs = edge_values(&mut g, n);
                let (s, b) = (g.f32_normalish(1.0), g.f32_normalish(0.1));
                let mut want = vec![0.0f32; n];
                axpb_scalar(s, b, &xs, &mut want);
                let mut got = vec![0.0f32; n];
                (k.axpb)(s, b, &xs, &mut got);
                let mut inp = xs.clone();
                (k.axpb_in_place)(s, b, &mut inp);
                for i in 0..n {
                    assert_eq!(want[i].to_bits(), got[i].to_bits(), "{level:?} n={n}");
                    assert_eq!(want[i].to_bits(), inp[i].to_bits());
                }
            }
        }
    }

    #[test]
    fn fit_sums_levels_and_phases_agree_bitwise() {
        let mut g = Gen::new(35);
        let v: Vec<f32> = (0..1000).map(|_| g.f32_normalish(0.05)).collect();
        let t: Vec<f32> = (0..1000).map(|_| g.f32_normalish(0.05)).collect();
        // reference: element-by-element push
        let mut reference = FitSums::new();
        for (&a, &b) in v.iter().zip(&t) {
            reference.push(a, b);
        }
        for level in available_levels() {
            let k = kernels_for(level).unwrap();
            // deliberately misaligned chunking to exercise the phase logic
            for chunk in [1usize, 2, 3, 4, 5, 7, 8, 64, 1000] {
                let mut acc = FitSums::new();
                for (cv, ct) in v.chunks(chunk).zip(t.chunks(chunk)) {
                    (k.fit_update)(&mut acc, cv, ct);
                }
                let (n0, a0, b0, c0, d0) = reference.totals();
                let (n1, a1, b1, c1, d1) = acc.totals();
                assert_eq!(n0, n1);
                assert_eq!(a0.to_bits(), a1.to_bits(), "{level:?} chunk={chunk}");
                assert_eq!(b0.to_bits(), b1.to_bits(), "{level:?} chunk={chunk}");
                assert_eq!(c0.to_bits(), c1.to_bits(), "{level:?} chunk={chunk}");
                assert_eq!(d0.to_bits(), d1.to_bits(), "{level:?} chunk={chunk}");
            }
        }
    }

    #[test]
    fn force_level_overrides_and_restores() {
        let resolved = kernels().level;
        assert!(force_level(Some(Level::Scalar)));
        assert_eq!(kernels().level, Level::Scalar);
        assert!(force_level(None));
        assert_eq!(kernels().level, resolved);
        // an unavailable level is rejected without changing the dispatch
        #[cfg(not(target_arch = "x86_64"))]
        {
            assert!(!force_level(Some(Level::Avx2)));
            assert_eq!(kernels().level, resolved);
        }
    }

    #[test]
    fn crc32c_known_vectors() {
        // RFC 3720 Appendix B.4 check value for "123456789"
        assert_eq!(crc32c(0, b"123456789"), 0xE306_9283);
        assert_eq!(crc32c(0, b""), 0);
        // 32 zero bytes (an iSCSI test vector)
        assert_eq!(crc32c(0, &[0u8; 32]), 0x8A91_36AA);
        // incremental == one-shot (the writer seals variables in pieces)
        let data: Vec<u8> = (0u16..1000).map(|i| (i % 251) as u8).collect();
        let whole = crc32c(0, &data);
        let (a, b) = data.split_at(333);
        assert_eq!(crc32c(crc32c(0, a), b), whole);
    }

    #[test]
    fn crc32c_paths_agree() {
        let mut g = Gen::new(40);
        for n in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000] {
            let bytes: Vec<u8> =
                (0..n).map(|_| (g.u64() & 0xFF) as u8).collect();
            let dispatched = crc32c(0x1234_5678, &bytes);
            assert_eq!(dispatched, crc32c_reference(0x1234_5678, &bytes));
            // the scalar pin must not change the checksum, only the path
            assert!(force_level(Some(Level::Scalar)));
            assert_eq!(crc32c(0x1234_5678, &bytes), dispatched);
            assert!(force_level(None));
        }
    }

    #[test]
    fn xor_and_or_fold_levels_match_scalar() {
        let mut g = Gen::new(41);
        for level in available_levels() {
            let k = kernels_for(level).unwrap();
            for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 1000] {
                let a: Vec<u8> = (0..n).map(|_| (g.u64() & 0xFF) as u8).collect();
                let b: Vec<u8> = (0..n).map(|_| (g.u64() & 0xFF) as u8).collect();
                let mut want = vec![0u8; n];
                xor_bytes_scalar(&a, &b, &mut want);
                let mut got = vec![0u8; n];
                (k.xor_bytes)(&a, &b, &mut got);
                assert_eq!(want, got, "{level:?} xor n={n}");
                assert_eq!(
                    (k.or_fold)(&a),
                    or_fold_scalar(&a),
                    "{level:?} or_fold n={n}"
                );
            }
            // single set bit at every word/byte position survives the fold
            for bit in [0usize, 7, 8, 63, 64, 65, 511] {
                let mut a = vec![0u8; 70];
                a[bit / 8] |= 1 << (bit % 8);
                assert_eq!(
                    (k.or_fold)(&a),
                    or_fold_scalar(&a),
                    "{level:?} bit={bit}"
                );
                assert_ne!((k.or_fold)(&a), 0);
            }
        }
        // the free wrappers go through the dispatched table
        let a = [1u8, 2, 3];
        let b = [255u8, 0, 3];
        let mut out = [0u8; 3];
        xor_bytes(&a, &b, &mut out);
        assert_eq!(out, [254, 2, 0]);
        assert_eq!(or_fold_words(&a), u64::from_le_bytes([1, 2, 3, 0, 0, 0, 0, 0]));
        assert_eq!(or_fold_words(&[]), 0);
    }

    #[test]
    fn quantize_one_em_basics() {
        // ties round to even at S1E4M2 (mirrors omc::quantize's tests)
        assert_eq!(quantize_one_em(1.125, 4, 2), 1.0);
        assert_eq!(quantize_one_em(1.375, 4, 2), 1.5);
        // signed zeros survive
        assert_eq!(quantize_one_em(0.0, 3, 7).to_bits(), 0.0f32.to_bits());
        assert_eq!(quantize_one_em(-0.0, 3, 7).to_bits(), (-0.0f32).to_bits());
    }
}
