//! Declarative command-line flag parser (no `clap` offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, required
//! arguments with defaults, and auto-generated `--help` text. Used by the
//! main binary and every example/bench driver.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug)]
struct FlagSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_bool: bool,
}

/// A tiny declarative argument parser.
///
/// (`no_run`: doctest binaries don't inherit the rpath to
/// libxla_extension.so, so they compile but cannot execute in this image.)
///
/// ```no_run
/// use omc_fl::util::cli::Args;
/// let mut args = Args::new("demo", "example parser");
/// args.flag("rounds", "number of federated rounds", Some("100"));
/// args.flag("format", "SxEyMz format", Some("S1E4M14"));
/// args.bool_flag("verbose", "chatty logging");
/// let m = args.parse_from(vec!["--rounds".into(), "25".into()]).unwrap();
/// assert_eq!(m.get_usize("rounds").unwrap(), 25);
/// assert_eq!(m.get("format").unwrap(), "S1E4M14");
/// assert!(!m.get_bool("verbose"));
/// ```
pub struct Args {
    prog: String,
    about: String,
    specs: Vec<FlagSpec>,
}

/// Parsed flag values.
pub struct Matches {
    values: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    /// positional (non-flag) arguments in order
    pub positional: Vec<String>,
}

impl Args {
    pub fn new(prog: &str, about: &str) -> Self {
        Self {
            prog: prog.to_string(),
            about: about.to_string(),
            specs: Vec::new(),
        }
    }

    /// Register a value flag; `default = None` makes it required.
    pub fn flag(&mut self, name: &str, help: &str, default: Option<&str>) -> &mut Self {
        self.specs.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: default.map(|s| s.to_string()),
            is_bool: false,
        });
        self
    }

    /// Register a boolean flag (defaults to false).
    pub fn bool_flag(&mut self, name: &str, help: &str) -> &mut Self {
        self.specs.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_bool: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{} — {}", self.prog, self.about);
        let _ = writeln!(out, "\nOptions:");
        for s in &self.specs {
            let tail = if s.is_bool {
                String::new()
            } else if let Some(d) = &s.default {
                format!(" (default: {d})")
            } else {
                " (required)".to_string()
            };
            let _ = writeln!(out, "  --{:<24} {}{}", s.name, s.help, tail);
        }
        let _ = writeln!(out, "  --{:<24} {}", "help", "print this message");
        out
    }

    /// Parse `std::env::args().skip(1)`. Exits with usage on `--help`.
    pub fn parse(&self) -> Matches {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse_from(argv) {
            Ok(m) => m,
            Err(HelpOrError::Help) => {
                print!("{}", self.usage());
                std::process::exit(0);
            }
            Err(HelpOrError::Error(e)) => {
                eprintln!("error: {e}\n\n{}", self.usage());
                std::process::exit(2);
            }
        }
    }

    /// Pure parsing entry (testable; returns Err(Help) on --help).
    pub fn parse_from(&self, argv: Vec<String>) -> Result<Matches, HelpOrError> {
        let mut values = BTreeMap::new();
        let mut bools = BTreeMap::new();
        let mut positional = Vec::new();
        for s in &self.specs {
            if s.is_bool {
                bools.insert(s.name.clone(), false);
            } else if let Some(d) = &s.default {
                values.insert(s.name.clone(), d.clone());
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(HelpOrError::Help);
            }
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| HelpOrError::Error(format!("unknown flag --{name}")))?;
                if spec.is_bool {
                    if let Some(v) = inline {
                        let b = v.parse::<bool>().map_err(|_| {
                            HelpOrError::Error(format!("--{name} expects true/false"))
                        })?;
                        bools.insert(name, b);
                    } else {
                        bools.insert(name, true);
                    }
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it.next().ok_or_else(|| {
                            HelpOrError::Error(format!("--{name} needs a value"))
                        })?,
                    };
                    values.insert(name, v);
                }
            } else {
                positional.push(arg);
            }
        }
        for s in &self.specs {
            if !s.is_bool && !values.contains_key(&s.name) {
                return Err(HelpOrError::Error(format!("--{} is required", s.name)));
            }
        }
        Ok(Matches {
            values,
            bools,
            positional,
        })
    }
}

#[derive(Debug)]
pub enum HelpOrError {
    Help,
    Error(String),
}

impl std::fmt::Display for HelpOrError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HelpOrError::Help => write!(f, "help requested"),
            HelpOrError::Error(e) => write!(f, "{e}"),
        }
    }
}

impl Matches {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }

    pub fn get_usize(&self, name: &str) -> anyhow::Result<usize> {
        let v = self
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing --{name}"))?;
        v.parse()
            .map_err(|_| anyhow::anyhow!("--{name}: {v:?} is not an unsigned integer"))
    }

    pub fn get_u64(&self, name: &str) -> anyhow::Result<u64> {
        let v = self
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing --{name}"))?;
        v.parse()
            .map_err(|_| anyhow::anyhow!("--{name}: {v:?} is not a u64"))
    }

    pub fn get_f64(&self, name: &str) -> anyhow::Result<f64> {
        let v = self
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing --{name}"))?;
        v.parse()
            .map_err(|_| anyhow::anyhow!("--{name}: {v:?} is not a number"))
    }

    pub fn get_f32(&self, name: &str) -> anyhow::Result<f32> {
        Ok(self.get_f64(name)? as f32)
    }

    /// Comma-separated list value (`--formats S1E4M14,S1E3M7`); empty
    /// string → empty list. Items are trimmed.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        match self.get(name) {
            None => Vec::new(),
            Some(v) if v.trim().is_empty() => Vec::new(),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args() -> Args {
        let mut a = Args::new("t", "test");
        a.flag("rounds", "rounds", Some("10"));
        a.flag("name", "required name", None);
        a.bool_flag("fast", "go fast");
        a
    }

    fn parse(argv: &[&str]) -> Result<Matches, HelpOrError> {
        args().parse_from(argv.iter().map(|s| s.to_string()).collect())
    }

    #[test]
    fn defaults_and_values() {
        let m = parse(&["--name", "x"]).unwrap();
        assert_eq!(m.get_usize("rounds").unwrap(), 10);
        assert_eq!(m.get("name"), Some("x"));
        assert!(!m.get_bool("fast"));
    }

    #[test]
    fn equals_syntax_and_bools() {
        let m = parse(&["--name=y", "--rounds=42", "--fast"]).unwrap();
        assert_eq!(m.get_usize("rounds").unwrap(), 42);
        assert!(m.get_bool("fast"));
        let m = parse(&["--name=y", "--fast=false"]).unwrap();
        assert!(!m.get_bool("fast"));
    }

    #[test]
    fn missing_required() {
        assert!(matches!(parse(&[]), Err(HelpOrError::Error(_))));
    }

    #[test]
    fn unknown_flag() {
        let e = parse(&["--name", "x", "--nope"]);
        assert!(matches!(e, Err(HelpOrError::Error(_))));
    }

    #[test]
    fn help_flag() {
        assert!(matches!(parse(&["-h"]), Err(HelpOrError::Help)));
    }

    #[test]
    fn positional_passthrough() {
        let m = parse(&["--name", "x", "pos1", "pos2"]).unwrap();
        assert_eq!(m.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn usage_lists_flags() {
        let u = args().usage();
        assert!(u.contains("--rounds"));
        assert!(u.contains("(required)"));
        assert!(u.contains("default: 10"));
    }

    #[test]
    fn list_values() {
        let mut a = Args::new("t", "test");
        a.flag("formats", "list", Some(""));
        let m = a
            .parse_from(vec!["--formats".into(), "S1E4M14, S1E3M7,".into()])
            .unwrap();
        assert_eq!(m.get_list("formats"), vec!["S1E4M14", "S1E3M7"]);
        let m = a.parse_from(vec![]).unwrap();
        assert!(m.get_list("formats").is_empty());
        assert!(m.get_list("missing").is_empty());
    }

    #[test]
    fn numeric_errors() {
        let m = parse(&["--name", "x", "--rounds", "abc"]).unwrap();
        assert!(m.get_usize("rounds").is_err());
    }
}
