//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! [`SplitMix64`] seeds [`Xoshiro256pp`] (xoshiro256++, Blackman–Vigna),
//! which provides uniform ints/floats, Box–Muller normals, shuffles and
//! weighted choice. Every stochastic decision in the system — data
//! synthesis, client sampling, PPQ variable selection — flows through this
//! module with an explicit seed, so whole federated runs replay exactly.

/// SplitMix64: used for seeding and cheap stateless hashing.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Stateless 64-bit mix of several stream identifiers — used to derive
/// per-(round, client, purpose) seeds that are independent by construction.
#[inline]
pub fn hash_seed(parts: &[u64]) -> u64 {
    let mut h = 0x243F_6A88_85A3_08D3u64; // pi fractional bits
    for &p in parts {
        let mut sm = SplitMix64::new(h ^ p);
        h = sm.next_u64();
    }
    h
}

/// xoshiro256++ — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // all-zero state is invalid; SplitMix64 cannot produce 4 zeros from
        // any seed, but keep the guard for clarity
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Self { s }
    }

    /// Derive an independent stream for a labeled purpose.
    pub fn derive(&self, parts: &[u64]) -> Xoshiro256pp {
        let mut all = vec![self.s[0] ^ self.s[2]];
        all.extend_from_slice(parts);
        Xoshiro256pp::new(hash_seed(&all))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our use;
    /// modulo bias is < 2^-32 for the small n we draw and irrelevant to the
    /// simulation, but we use the widening-multiply method anyway).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box–Muller (pair cached).
    pub fn next_normal(&mut self) -> f64 {
        // draw until u1 > 0 to avoid ln(0)
        let mut u1 = self.next_f64();
        while u1 <= f64::MIN_POSITIVE {
            u1 = self.next_f64();
        }
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a slice with N(0, sigma^2) f32 samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = (self.next_normal() as f32) * sigma;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (uniform, order randomized).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher–Yates: first k entries are the sample
        for i in 0..k {
            let j = i + self.next_below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Weighted index choice proportional to `weights` (all >= 0, sum > 0).
    pub fn choice_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "choice_weighted: zero total weight");
        let mut r = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // reference values for seed 1234567 (from the public-domain C impl)
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_and_seed_sensitive() {
        let mut a = Xoshiro256pp::new(42);
        let mut b = Xoshiro256pp::new(42);
        let mut c = Xoshiro256pp::new(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256pp::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256pp::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Xoshiro256pp::new(3);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let v = r.next_below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Xoshiro256pp::new(5);
        for _ in 0..100 {
            let s = r.sample_indices(20, 8);
            assert_eq!(s.len(), 8);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 8);
            assert!(sorted.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn sample_indices_uniformity() {
        // each of 10 indices should appear ~ k/n of the time
        let mut r = Xoshiro256pp::new(17);
        let mut counts = [0usize; 10];
        let trials = 20_000;
        for _ in 0..trials {
            for i in r.sample_indices(10, 3) {
                counts[i] += 1;
            }
        }
        let expect = trials as f64 * 0.3;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expect).abs() < expect * 0.1,
                "index {i}: {c} vs {expect}"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn hash_seed_order_sensitive() {
        assert_ne!(hash_seed(&[1, 2]), hash_seed(&[2, 1]));
        assert_eq!(hash_seed(&[1, 2]), hash_seed(&[1, 2]));
    }

    #[test]
    fn derive_streams_independent() {
        let base = Xoshiro256pp::new(100);
        let mut a = base.derive(&[1]);
        let mut b = base.derive(&[2]);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn choice_weighted_respects_weights() {
        let mut r = Xoshiro256pp::new(21);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.choice_weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }
}
