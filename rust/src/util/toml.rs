//! TOML-subset parser for experiment configs (no `toml` crate offline).
//!
//! Supported grammar — everything the configs in `configs/` use:
//! `[section]` and `[section.sub]` headers, `key = value` with string,
//! integer, float, boolean and homogeneous-array values, `#` comments.
//! Values land in a flat `section.key -> Value` map, which the typed config
//! layer (`coordinator::config`) consumes.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Flat `section.key` map; keys in the root table have no prefix.
pub type Table = BTreeMap<String, Value>;

pub fn parse(input: &str) -> Result<Table, TomlError> {
    let mut table = Table::new();
    let mut section = String::new();
    for (ln, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| TomlError {
            line: ln + 1,
            msg: msg.to_string(),
        };
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err("unterminated section header"))?
                .trim();
            if name.is_empty() {
                return Err(err("empty section name"));
            }
            section = name.to_string();
            continue;
        }
        let eq = line.find('=').ok_or_else(|| err("expected key = value"))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err("empty key"));
        }
        let vtxt = line[eq + 1..].trim();
        let value = parse_value(vtxt).map_err(|m| err(&m))?;
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        if table.insert(full.clone(), value).is_some() {
            return Err(err(&format!("duplicate key {full:?}")));
        }
    }
    Ok(table)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(txt: &str) -> Result<Value, String> {
    if txt.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = txt.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        // minimal escapes
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => return Err(format!("bad escape {other:?}")),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(Value::Str(out));
    }
    if let Some(inner) = txt.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            items.push(parse_value(part.trim())?);
        }
        return Ok(Value::Arr(items));
    }
    match txt {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = txt.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = txt.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value {txt:?}"))
}

/// Split on commas that are not inside quotes (arrays of strings).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_experiment_config_shape() {
        let txt = r#"
            # experiment
            name = "table1"
            rounds = 300

            [omc]
            format = "S1E4M14"
            quantize_fraction = 0.9   # PPQ
            weights_only = true

            [fl]
            clients = 64
            clients_per_round = 16
            lrs = [0.1, 0.05]
        "#;
        let t = parse(txt).unwrap();
        assert_eq!(t["name"].as_str(), Some("table1"));
        assert_eq!(t["rounds"].as_i64(), Some(300));
        assert_eq!(t["omc.format"].as_str(), Some("S1E4M14"));
        assert_eq!(t["omc.quantize_fraction"].as_f64(), Some(0.9));
        assert_eq!(t["omc.weights_only"].as_bool(), Some(true));
        assert_eq!(t["fl.lrs"].as_arr().unwrap().len(), 2);
    }

    #[test]
    fn comments_and_blank_lines() {
        let t = parse("# only a comment\n\nx = 1 # trailing\n").unwrap();
        assert_eq!(t["x"].as_i64(), Some(1));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let t = parse("s = \"a#b\"").unwrap();
        assert_eq!(t["s"].as_str(), Some("a#b"));
    }

    #[test]
    fn string_escapes() {
        let t = parse(r#"s = "a\nb\"c""#).unwrap();
        assert_eq!(t["s"].as_str(), Some("a\nb\"c"));
    }

    #[test]
    fn arrays() {
        let t = parse(r#"a = [1, 2, 3]
                         b = ["x", "y,z"]
                         c = []"#)
        .unwrap();
        assert_eq!(t["a"].as_arr().unwrap().len(), 3);
        assert_eq!(t["b"].as_arr().unwrap()[1].as_str(), Some("y,z"));
        assert!(t["c"].as_arr().unwrap().is_empty());
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("x = 1\ny =").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse("[bad\n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(parse("x = 1\nx = 2").is_err());
    }

    #[test]
    fn numbers_with_underscores() {
        let t = parse("n = 1_000_000").unwrap();
        assert_eq!(t["n"].as_i64(), Some(1_000_000));
    }
}
