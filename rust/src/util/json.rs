//! Minimal JSON parser/writer (no `serde` offline).
//!
//! Covers the full JSON grammar we produce and consume: the AOT
//! `manifest.json`, metrics output, and experiment result files. Numbers are
//! kept as f64 (adequate: manifests hold shapes/counts < 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- accessors ------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required manifest fields.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing required key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u32::MAX as f64 * 4096.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    // ---- writer ---------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity tokens; emitting them would
                    // make the whole file unparseable (e.g. a summary whose
                    // final_train_loss is NaN after a fully-dropped round)
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for building metrics objects.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

// ---- parser -------------------------------------------------------------

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        b: input.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.i,
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map_or(false, |c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (sufficient for our files); surrogate
                            // pairs land as replacement chars
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let txt = r#"{
            "config": {"batch": 4, "streaming": false, "name": "tiny"},
            "variables": [{"name": "w", "shape": [3, 4], "kind": "weight"}],
            "total_params": 12
        }"#;
        let j = parse(txt).unwrap();
        assert_eq!(j.get("total_params").unwrap().as_usize(), Some(12));
        assert_eq!(
            j.get("config").unwrap().get("name").unwrap().as_str(),
            Some("tiny")
        );
        let vars = j.get("variables").unwrap().as_arr().unwrap();
        assert_eq!(vars[0].get("shape").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn roundtrip() {
        let txt = r#"{"a":[1,2.5,-3e2,true,false,null,"x\n\"y\""],"b":{}}"#;
        let j = parse(txt).unwrap();
        let j2 = parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "nul", "1 2", "\"\\q\""] {
            assert!(parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn unicode_and_escapes() {
        let j = parse(r#""héllo A""#).unwrap();
        assert_eq!(j.as_str(), Some("héllo A"));
    }

    #[test]
    fn numbers() {
        for (t, v) in [("0", 0.0), ("-1", -1.0), ("3.25", 3.25), ("1e3", 1000.0)] {
            assert_eq!(parse(t).unwrap().as_f64(), Some(v), "{t}");
        }
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // JSON has no NaN/Infinity; the emitted file must stay parseable
        for x in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let obj = super::obj(vec![("v", super::num(x))]);
            let text = obj.to_string();
            assert_eq!(text, r#"{"v":null}"#);
            assert!(parse(&text).unwrap().get("v").unwrap().as_f64().is_none());
        }
    }

    #[test]
    fn writer_escapes_control_chars() {
        let j = Json::Str("a\u{1}b".into());
        assert_eq!(j.to_string(), "\"a\\u0001b\"");
        assert_eq!(parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn req_reports_key() {
        let j = parse("{}").unwrap();
        let e = j.req("missing_key").unwrap_err().to_string();
        assert!(e.contains("missing_key"));
    }
}
