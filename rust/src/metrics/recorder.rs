//! Round-log recording: per-round metrics to CSV + a JSON summary, the raw
//! material for EXPERIMENTS.md and the figure-reproduction examples.
//!
//! Asynchronous runs (`fl::async_round`) record one [`RoundRecord`] per
//! *commit* (the async analog of a round) plus a parallel [`CommitRecord`]
//! carrying the async-only metrics: the per-commit staleness histogram,
//! buffer occupancy, stale-discarded update bytes, snapshot-ring memory,
//! and the deterministic virtual-time stamps. Everything in a
//! `CommitRecord` is a pure function of `(config, seed)` — virtual time
//! comes from the latency model, never the wall clock — so these fields
//! may appear in the byte-deterministic sweep summaries.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::fl::population::{PopulationRoundStats, NUM_CLASSES};
use crate::util::json::{self, Json};

/// One federated round's metrics.
#[derive(Clone, Debug, Default)]
pub struct RoundRecord {
    pub round: usize,
    pub train_loss: f64,
    pub eval_loss: f64,
    pub eval_wer: f64,
    /// bytes server->clients this round
    pub down_bytes: usize,
    /// bytes clients->server this round
    pub up_bytes: usize,
    /// uplink bytes that arrived past the reporting deadline (spent but
    /// excluded from aggregation; subset of `up_bytes`)
    pub up_bytes_discarded: usize,
    /// clients sampled into the cohort
    pub sampled: usize,
    /// clients whose update was aggregated
    pub completed: usize,
    /// clients that dropped after the downlink
    pub dropped: usize,
    /// clients that reported after the deadline
    pub late: usize,
    /// clients killed by chaos: crashed mid-round, or gave up after
    /// exhausting their uplink retries (zero when chaos is off)
    pub crashed: usize,
    /// uplink frames the server rejected this round — corrupt attempts
    /// caught by the wire-integrity check plus duplicate replays
    pub frames_rejected: u64,
    /// the subset of `up_bytes` spent on rejected frames
    pub up_bytes_rejected: usize,
    /// uplink bytes the delta wire stage saved vs verbatim framing this
    /// round (`up_bytes` already reflects the smaller delta frames; this
    /// is the reduction, zero when `[delta]` is off)
    pub up_bytes_delta_saved: usize,
    /// uplink bytes the sparse stage saved vs dense framing this round
    /// (`up_bytes` already reflects the smaller sparse records; this is
    /// the reduction, zero when `[sparse]` is off)
    pub up_bytes_sparse_saved: usize,
    /// coordinates selected onto the wire by the sparse stage this round
    pub sparse_selected: u64,
    /// coordinates eligible for sparse selection this round (selected +
    /// left behind in error-feedback residuals)
    pub sparse_total: u64,
    /// sum of squared error-feedback residual coordinates banked by this
    /// round's clients (f64 accumulation; zero when `[sparse]` is off)
    pub sparse_residual_sq: f64,
    pub round_seconds: f64,
}

/// One async commit's deterministic metrics (see the module docs).
#[derive(Clone, Debug, Default)]
pub struct CommitRecord {
    /// commit index (== the recorded round index)
    pub commit: usize,
    /// updates folded into this commit (the buffer K)
    pub folded: usize,
    /// mean staleness of the folded updates
    pub mean_staleness: f64,
    /// staleness histogram of the folded updates (index = staleness)
    pub staleness_hist: Vec<usize>,
    /// mean buffer fill observed at each event of the commit window
    pub mean_occupancy: f64,
    /// arrival/drop events processed during the window
    pub window_events: usize,
    /// updates discarded as too stale during the window
    pub discarded_updates: usize,
    /// uplink bytes of those discarded updates (spent, never folded)
    pub discarded_bytes: usize,
    /// compressed snapshot-ring memory after the commit, bytes
    pub ring_bytes: usize,
    /// virtual time the commit fired (simulated seconds — deterministic)
    pub virtual_time: f64,
    /// RMS parameter drift of this commit vs the version it replaced
    pub param_drift: f64,
    /// transient server-side failures before this commit stuck (chaos);
    /// each added virtual-time backoff but never lost the commit
    pub commit_failures: u32,
}

/// Collects round records and writes them out.
#[derive(Debug, Default)]
pub struct Recorder {
    pub records: Vec<RoundRecord>,
    /// async-only per-commit records (empty for synchronous runs),
    /// parallel to `records`
    pub commits: Vec<CommitRecord>,
    /// population-mode per-round records (empty otherwise), parallel to
    /// `records`; everything in them is a pure function of
    /// `(config, seed)` — see `fl::population`
    pub populations: Vec<PopulationRoundStats>,
    pub label: String,
}

impl Recorder {
    pub fn new(label: &str) -> Self {
        Self {
            records: Vec::new(),
            commits: Vec::new(),
            populations: Vec::new(),
            label: label.to_string(),
        }
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.records.push(r);
    }

    /// Record one async commit's metrics (async runs push one per round).
    pub fn push_commit(&mut self, c: CommitRecord) {
        self.commits.push(c);
    }

    /// Whether this run recorded async commits.
    pub fn is_async(&self) -> bool {
        !self.commits.is_empty()
    }

    /// Record one population-mode round's facts (population runs push one
    /// per round).
    pub fn push_population(&mut self, p: PopulationRoundStats) {
        self.populations.push(p);
    }

    /// Whether this run recorded population-mode rounds.
    pub fn is_population(&self) -> bool {
        !self.populations.is_empty()
    }

    /// Rejection-sampling attempts across the run.
    pub fn total_sample_attempts(&self) -> u64 {
        self.populations.iter().map(|p| p.sample.attempts).sum()
    }

    /// Candidates rejected because they already sat in the cohort.
    pub fn total_duplicate_rejections(&self) -> u64 {
        self.populations
            .iter()
            .map(|p| p.sample.duplicate_rejections)
            .sum()
    }

    /// Candidates rejected because their churn duty cycle had them out.
    pub fn total_churn_rejections(&self) -> u64 {
        self.populations
            .iter()
            .map(|p| p.sample.churn_rejections)
            .sum()
    }

    /// Candidates rejected by the diurnal availability wave.
    pub fn total_wave_rejections(&self) -> u64 {
        self.populations
            .iter()
            .map(|p| p.sample.wave_rejections)
            .sum()
    }

    /// Mean analytic active-fleet estimate over the run (NaN when the run
    /// was not in population mode).
    pub fn mean_active_estimate(&self) -> f64 {
        if self.populations.is_empty() {
            return f64::NAN;
        }
        let sum: f64 = self
            .populations
            .iter()
            .map(|p| p.sample.active_estimate)
            .sum();
        sum / self.populations.len() as f64
    }

    /// Clients sampled per device class, summed over the run.
    pub fn class_sampled_totals(&self) -> [u64; NUM_CLASSES] {
        let mut out = [0u64; NUM_CLASSES];
        for p in &self.populations {
            for (o, &n) in out.iter_mut().zip(&p.sample.class_sampled) {
                *o += n;
            }
        }
        out
    }

    /// Clients that completed per device class, summed over the run.
    pub fn class_completed_totals(&self) -> [u64; NUM_CLASSES] {
        let mut out = [0u64; NUM_CLASSES];
        for p in &self.populations {
            for (o, &n) in out.iter_mut().zip(&p.class_completed) {
                *o += n;
            }
        }
        out
    }

    /// Edge→root frames shipped across the run.
    pub fn total_edge_frames(&self) -> u64 {
        self.populations.iter().map(|p| p.edge.frames).sum()
    }

    /// Edge→root bytes shipped across the run.
    pub fn total_edge_up_bytes(&self) -> u64 {
        self.populations.iter().map(|p| p.edge.up_bytes).sum()
    }

    /// Bytes the edge-hop delta stage saved across the run.
    pub fn total_edge_delta_saved(&self) -> u64 {
        self.populations.iter().map(|p| p.edge.delta_saved).sum()
    }

    /// Staleness histogram merged over every commit (index = staleness).
    pub fn staleness_histogram(&self) -> Vec<usize> {
        let len = self
            .commits
            .iter()
            .map(|c| c.staleness_hist.len())
            .max()
            .unwrap_or(0);
        let mut merged = vec![0usize; len];
        for c in &self.commits {
            for (s, &n) in c.staleness_hist.iter().enumerate() {
                merged[s] += n;
            }
        }
        merged
    }

    /// Mean staleness over every folded update (NaN with no commits).
    pub fn mean_staleness(&self) -> f64 {
        let hist = self.staleness_histogram();
        let total: usize = hist.iter().sum();
        if total == 0 {
            return f64::NAN;
        }
        let weighted: usize =
            hist.iter().enumerate().map(|(s, &n)| s * n).sum();
        weighted as f64 / total as f64
    }

    /// Largest staleness any folded update carried.
    pub fn max_staleness(&self) -> usize {
        self.staleness_histogram()
            .iter()
            .rposition(|&n| n > 0)
            .unwrap_or(0)
    }

    /// Event-weighted mean buffer occupancy over the run (NaN when sync).
    pub fn mean_buffer_occupancy(&self) -> f64 {
        let events: usize = self.commits.iter().map(|c| c.window_events).sum();
        if events == 0 {
            return f64::NAN;
        }
        let weighted: f64 = self
            .commits
            .iter()
            .map(|c| c.mean_occupancy * c.window_events as f64)
            .sum();
        weighted / events as f64
    }

    /// Updates discarded as too stale across the run.
    pub fn total_discarded_updates(&self) -> usize {
        self.commits.iter().map(|c| c.discarded_updates).sum()
    }

    /// Uplink bytes spent on stale-discarded updates across the run.
    pub fn total_discarded_bytes(&self) -> usize {
        self.commits.iter().map(|c| c.discarded_bytes).sum()
    }

    /// Snapshot-ring memory after the final commit, bytes.
    pub fn last_ring_bytes(&self) -> usize {
        self.commits.last().map(|c| c.ring_bytes).unwrap_or(0)
    }

    /// Virtual time of the final commit (simulated seconds; NaN when sync).
    pub fn final_virtual_time(&self) -> f64 {
        self.commits
            .last()
            .map(|c| c.virtual_time)
            .unwrap_or(f64::NAN)
    }

    pub fn last(&self) -> Option<&RoundRecord> {
        self.records.last()
    }

    /// Mean WER over the final `k` evaluated rounds (the number the tables
    /// report; evaluation cadence may skip rounds, so filter on eval_wer
    /// having been set).
    pub fn final_wer(&self, k: usize) -> f64 {
        let evals: Vec<f64> = self
            .records
            .iter()
            .filter(|r| r.eval_wer >= 0.0 && r.eval_loss > 0.0)
            .map(|r| r.eval_wer)
            .collect();
        if evals.is_empty() {
            return f64::NAN;
        }
        let tail = &evals[evals.len().saturating_sub(k)..];
        tail.iter().sum::<f64>() / tail.len() as f64
    }

    /// Total communication (both directions) in bytes.
    pub fn total_comm_bytes(&self) -> usize {
        self.records
            .iter()
            .map(|r| r.down_bytes + r.up_bytes)
            .sum()
    }

    /// Total server→client bytes across the run.
    pub fn total_down_bytes(&self) -> usize {
        self.records.iter().map(|r| r.down_bytes).sum()
    }

    /// Total client→server bytes across the run.
    pub fn total_up_bytes(&self) -> usize {
        self.records.iter().map(|r| r.up_bytes).sum()
    }

    /// Total uplink bytes spent by past-deadline clients (subset of
    /// [`total_up_bytes`](Self::total_up_bytes)).
    pub fn total_up_bytes_discarded(&self) -> usize {
        self.records.iter().map(|r| r.up_bytes_discarded).sum()
    }

    /// Total uplink frames the server rejected across the run (corrupt
    /// attempts + duplicate replays; zero when chaos is off).
    pub fn total_frames_rejected(&self) -> u64 {
        self.records.iter().map(|r| r.frames_rejected).sum()
    }

    /// Total uplink bytes spent on rejected frames (subset of
    /// [`total_up_bytes`](Self::total_up_bytes)).
    pub fn total_up_bytes_rejected(&self) -> usize {
        self.records.iter().map(|r| r.up_bytes_rejected).sum()
    }

    /// Total uplink bytes the delta wire stage saved vs verbatim framing
    /// (zero when `[delta]` is off).
    pub fn total_up_bytes_delta_saved(&self) -> usize {
        self.records.iter().map(|r| r.up_bytes_delta_saved).sum()
    }

    /// Total uplink bytes the sparse stage saved vs dense framing (zero
    /// when `[sparse]` is off).
    pub fn total_up_bytes_sparse_saved(&self) -> usize {
        self.records.iter().map(|r| r.up_bytes_sparse_saved).sum()
    }

    /// Coordinates the sparse stage put on the wire across the run.
    pub fn total_sparse_selected(&self) -> u64 {
        self.records.iter().map(|r| r.sparse_selected).sum()
    }

    /// Coordinates eligible for sparse selection across the run.
    pub fn total_sparse_total(&self) -> u64 {
        self.records.iter().map(|r| r.sparse_total).sum()
    }

    /// Fraction of eligible coordinates *withheld* from the wire by the
    /// sparse stage (0.0 when the stage is off or nothing was eligible).
    pub fn sparsity(&self) -> f64 {
        let total = self.total_sparse_total();
        if total == 0 {
            return 0.0;
        }
        1.0 - self.total_sparse_selected() as f64 / total as f64
    }

    /// L2 norm of the error-feedback residual mass banked across the run
    /// (sqrt of the summed per-round squared norms; deterministic).
    pub fn sparse_residual_norm(&self) -> f64 {
        self.records
            .iter()
            .map(|r| r.sparse_residual_sq)
            .sum::<f64>()
            .sqrt()
    }

    /// Clients killed by chaos across the run (crashes + retry give-ups).
    pub fn total_crashed(&self) -> usize {
        self.records.iter().map(|r| r.crashed).sum()
    }

    /// Transient commit failures injected across the run (async chaos).
    pub fn total_commit_failures(&self) -> u64 {
        self.commits.iter().map(|c| u64::from(c.commit_failures)).sum()
    }

    /// `(round, WER)` for every evaluated round, in order — the figure
    /// curves, and the deterministic per-cell sweep summaries.
    pub fn eval_wer_curve(&self) -> Vec<(usize, f64)> {
        self.records
            .iter()
            .filter(|r| r.eval_wer >= 0.0 && r.eval_loss > 0.0)
            .map(|r| (r.round, r.eval_wer))
            .collect()
    }

    /// Rounds per minute over the whole run (the tables' Speed column).
    pub fn rounds_per_min(&self) -> f64 {
        let secs: f64 = self.records.iter().map(|r| r.round_seconds).sum();
        if secs == 0.0 {
            return 0.0;
        }
        60.0 * self.records.len() as f64 / secs
    }

    /// Mean fraction of sampled clients whose update was aggregated
    /// (1.0 for ideal cohorts; NaN when nothing was recorded).
    pub fn mean_completion_rate(&self) -> f64 {
        if self.records.is_empty() {
            return f64::NAN;
        }
        let rates: f64 = self
            .records
            .iter()
            .map(|r| r.completed as f64 / r.sampled.max(1) as f64)
            .sum();
        rates / self.records.len() as f64
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "round,train_loss,eval_loss,eval_wer,down_bytes,up_bytes,\
             up_bytes_discarded,sampled,completed,dropped,late,crashed,\
             frames_rejected,up_bytes_rejected,up_bytes_delta_saved,\
             up_bytes_sparse_saved,sparse_selected,sparse_total,\
             sparse_residual_sq,round_seconds\n",
        );
        for r in &self.records {
            out.push_str(&format!(
                "{},{:.6},{:.6},{:.4},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{:.6e},{:.6}\n",
                r.round,
                r.train_loss,
                r.eval_loss,
                r.eval_wer,
                r.down_bytes,
                r.up_bytes,
                r.up_bytes_discarded,
                r.sampled,
                r.completed,
                r.dropped,
                r.late,
                r.crashed,
                r.frames_rejected,
                r.up_bytes_rejected,
                r.up_bytes_delta_saved,
                r.up_bytes_sparse_saved,
                r.sparse_selected,
                r.sparse_total,
                r.sparse_residual_sq,
                r.round_seconds
            ));
        }
        out
    }

    pub fn summary_json(&self) -> Json {
        json::obj(vec![
            ("label", json::s(&self.label)),
            ("rounds", json::num(self.records.len() as f64)),
            ("final_wer", json::num(self.final_wer(3))),
            (
                "final_train_loss",
                json::num(self.last().map(|r| r.train_loss).unwrap_or(f64::NAN)),
            ),
            (
                "total_comm_bytes",
                json::num(self.total_comm_bytes() as f64),
            ),
            (
                "mean_completion_rate",
                json::num(self.mean_completion_rate()),
            ),
            ("rounds_per_min", json::num(self.rounds_per_min())),
        ])
    }

    /// CSV of the async per-commit records (empty string when sync). The
    /// staleness histogram is `|`-joined inside one column.
    pub fn commits_csv(&self) -> String {
        if self.commits.is_empty() {
            return String::new();
        }
        let mut out = String::from(
            "commit,folded,mean_staleness,staleness_hist,mean_occupancy,\
             window_events,discarded_updates,discarded_bytes,ring_bytes,\
             virtual_time,param_drift,commit_failures\n",
        );
        for c in &self.commits {
            let hist = c
                .staleness_hist
                .iter()
                .map(|n| n.to_string())
                .collect::<Vec<_>>()
                .join("|");
            out.push_str(&format!(
                "{},{},{:.4},{},{:.4},{},{},{},{},{:.6},{:.6e},{}\n",
                c.commit,
                c.folded,
                c.mean_staleness,
                hist,
                c.mean_occupancy,
                c.window_events,
                c.discarded_updates,
                c.discarded_bytes,
                c.ring_bytes,
                c.virtual_time,
                c.param_drift,
                c.commit_failures
            ));
        }
        out
    }

    /// CSV of the population-mode per-round records (empty string when the
    /// run was not in population mode). The per-class sampled/completed
    /// counters are `|`-joined inside one column each.
    pub fn populations_csv(&self) -> String {
        if self.populations.is_empty() {
            return String::new();
        }
        let mut out = String::from(
            "round,registered,edges,attempts,duplicate_rejections,\
             churn_rejections,wave_rejections,active_estimate,\
             class_sampled,class_completed,edge_frames,edge_up_bytes,\
             edge_delta_saved\n",
        );
        let join = |xs: &[u64]| {
            xs.iter()
                .map(|n| n.to_string())
                .collect::<Vec<_>>()
                .join("|")
        };
        for (round, p) in self.populations.iter().enumerate() {
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{:.2},{},{},{},{},{}\n",
                round,
                p.registered,
                p.edges,
                p.sample.attempts,
                p.sample.duplicate_rejections,
                p.sample.churn_rejections,
                p.sample.wave_rejections,
                p.sample.active_estimate,
                join(&p.sample.class_sampled),
                join(&p.class_completed),
                p.edge.frames,
                p.edge.up_bytes,
                p.edge.delta_saved
            ));
        }
        out
    }

    /// Write `<dir>/<label>.csv` and `<dir>/<label>.json` (plus
    /// `<dir>/<label>_commits.csv` for async runs and
    /// `<dir>/<label>_population.csv` for population-mode runs).
    pub fn write(&self, dir: &Path) -> Result<(PathBuf, PathBuf)> {
        fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let csv_path = dir.join(format!("{}.csv", self.label));
        let mut f = fs::File::create(&csv_path)?;
        f.write_all(self.to_csv().as_bytes())?;
        let json_path = dir.join(format!("{}.json", self.label));
        let mut f = fs::File::create(&json_path)?;
        f.write_all(self.summary_json().to_string().as_bytes())?;
        if self.is_async() {
            let commits_path = dir.join(format!("{}_commits.csv", self.label));
            let mut f = fs::File::create(&commits_path)?;
            f.write_all(self.commits_csv().as_bytes())?;
        }
        if self.is_population() {
            let pop_path = dir.join(format!("{}_population.csv", self.label));
            let mut f = fs::File::create(&pop_path)?;
            f.write_all(self.populations_csv().as_bytes())?;
        }
        Ok((csv_path, json_path))
    }
}

// ---- wall-clock serving metrics ------------------------------------------

/// Log-bucketed latency histogram: 64 power-of-two buckets from 1 µs, so
/// recording is one increment, merging across worker threads is one add
/// per bucket, and memory stays constant over an unbounded run. Quantiles
/// return the *upper bound* of the hit bucket (≤ 2× the true value —
/// plenty for p50/p99 trend lines).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    counts: [u64; Self::BUCKETS],
    total: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    const BUCKETS: usize = 64;
    /// bucket 0's upper bound, seconds
    const FLOOR_S: f64 = 1e-6;

    pub fn new() -> Self {
        Self {
            counts: [0; Self::BUCKETS],
            total: 0,
        }
    }

    /// Count one observation (clamped into the bucket range; non-finite
    /// observations land in bucket 0 rather than poisoning the histogram).
    pub fn record(&mut self, seconds: f64) {
        let idx = if !seconds.is_finite() || seconds <= Self::FLOOR_S {
            0
        } else {
            ((seconds / Self::FLOOR_S).log2().floor() as usize)
                .min(Self::BUCKETS - 1)
        };
        self.counts[idx] += 1;
        self.total += 1;
    }

    /// Fold another histogram in (worker-local → run-global).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
    }

    /// Observations recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Upper bound of the bucket holding the `q`-quantile observation
    /// (0.0 when empty).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target =
            ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, n) in self.counts.iter().enumerate() {
            cum += n;
            if cum >= target {
                return Self::FLOOR_S * f64::powi(2.0, i as i32 + 1);
            }
        }
        Self::FLOOR_S * f64::powi(2.0, Self::BUCKETS as i32)
    }
}

/// Wall-clock facts of one `omc-fl serve` run. Everything here is
/// *measured* — latency quantiles, throughput, queue behavior — so unlike
/// [`RoundRecord`]/[`CommitRecord`] none of it may ever appear in the
/// byte-deterministic sweep summaries; it lands in its own
/// `serve_report.json`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServeRecord {
    pub workers: usize,
    pub queue_depth: usize,
    pub commits: usize,
    /// uplink frames delivered through the bounded queue
    pub uplinks: usize,
    pub wall_s: f64,
    pub commits_per_sec: f64,
    /// transport bytes (both directions) per wall-clock second
    pub bytes_per_sec: f64,
    pub uplink_p50_s: f64,
    pub uplink_p99_s: f64,
    /// deepest uplink-queue fill observed
    pub queue_peak_depth: usize,
    /// admission-control rejections (runtime overflow + shutdown probe);
    /// distinct from the chaos engine's `frames_rejected` — these frames
    /// were valid, just not admitted on first offer
    pub queue_rejected_frames: u64,
    pub queue_rejected_bytes: u64,
    /// frame-buffer + client-scratch arena acquisitions
    pub arena_acquires: u64,
    /// acquisitions served by a fresh allocation
    pub arena_fresh: u64,
    /// acquisitions served from the pool (the saved allocations)
    pub arena_recycled: u64,
}

impl ServeRecord {
    /// Flatten a [`ServeReport`](crate::fl::serve::ServeReport) (both
    /// arenas folded together) for the JSON sidecar.
    pub fn from_report(r: &crate::fl::serve::ServeReport) -> Self {
        Self {
            workers: r.workers,
            queue_depth: r.queue_depth,
            commits: r.commits,
            uplinks: r.uplinks,
            wall_s: r.wall_s,
            commits_per_sec: r.commits_per_sec(),
            bytes_per_sec: r.bytes_per_sec(),
            uplink_p50_s: r.uplink_p50_s,
            uplink_p99_s: r.uplink_p99_s,
            queue_peak_depth: r.queue_peak_depth,
            queue_rejected_frames: r.rejected_total(),
            queue_rejected_bytes: r.queue_rejected_bytes,
            arena_acquires: r.frame_arena.acquires + r.scratch_arena.acquires,
            arena_fresh: r.frame_arena.fresh + r.scratch_arena.fresh,
            arena_recycled: r.frame_arena.recycled + r.scratch_arena.recycled,
        }
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("workers", json::num(self.workers as f64)),
            ("queue_depth", json::num(self.queue_depth as f64)),
            ("commits", json::num(self.commits as f64)),
            ("uplinks", json::num(self.uplinks as f64)),
            ("wall_s", json::num(self.wall_s)),
            ("commits_per_sec", json::num(self.commits_per_sec)),
            ("bytes_per_sec", json::num(self.bytes_per_sec)),
            ("uplink_p50_s", json::num(self.uplink_p50_s)),
            ("uplink_p99_s", json::num(self.uplink_p99_s)),
            (
                "queue_peak_depth",
                json::num(self.queue_peak_depth as f64),
            ),
            (
                "queue_rejected_frames",
                json::num(self.queue_rejected_frames as f64),
            ),
            (
                "queue_rejected_bytes",
                json::num(self.queue_rejected_bytes as f64),
            ),
            ("arena_acquires", json::num(self.arena_acquires as f64)),
            ("arena_fresh", json::num(self.arena_fresh as f64)),
            ("arena_recycled", json::num(self.arena_recycled as f64)),
        ])
    }
}

// ---- streaming CSV -------------------------------------------------------

/// Append-oriented CSV writer for long-running engines. [`Recorder::write`]
/// rebuilds whole files per call — fine for bounded sweeps, wrong for a
/// serving loop that logs for hours: the file would be rewritten from
/// scratch on every flush and the accumulating `Vec` grows without bound.
/// `CsvStream` holds one `BufWriter` open for the run; [`append`] stays in
/// the userspace buffer, and the engine calls [`flush`] on round/commit
/// boundaries so a crash loses at most the buffered tail, never the file.
///
/// [`append`]: Self::append
/// [`flush`]: Self::flush
#[derive(Debug)]
pub struct CsvStream {
    w: std::io::BufWriter<fs::File>,
    path: PathBuf,
}

impl CsvStream {
    /// Create (truncate) `path` and write the header row.
    pub fn create(path: &Path, header: &str) -> Result<Self> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        let f = fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut s = Self {
            w: std::io::BufWriter::new(f),
            path: path.to_path_buf(),
        };
        s.append(header)?;
        Ok(s)
    }

    /// Buffer one row (a trailing newline is added).
    pub fn append(&mut self, line: &str) -> Result<()> {
        self.w.write_all(line.as_bytes())?;
        self.w.write_all(b"\n")?;
        Ok(())
    }

    /// Push the buffered rows to disk — call on round/commit boundaries.
    pub fn flush(&mut self) -> Result<()> {
        self.w
            .flush()
            .with_context(|| format!("flushing {}", self.path.display()))
    }

    /// Where the stream writes.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, wer: f64) -> RoundRecord {
        RoundRecord {
            round,
            train_loss: 1.0,
            eval_loss: if wer >= 0.0 { 0.5 } else { 0.0 },
            eval_wer: wer,
            down_bytes: 100,
            up_bytes: 50,
            up_bytes_discarded: 0,
            sampled: 4,
            completed: 4,
            dropped: 0,
            late: 0,
            crashed: 0,
            frames_rejected: 0,
            up_bytes_rejected: 0,
            up_bytes_delta_saved: 0,
            up_bytes_sparse_saved: 0,
            sparse_selected: 0,
            sparse_total: 0,
            sparse_residual_sq: 0.0,
            round_seconds: 0.5,
        }
    }

    #[test]
    fn final_wer_averages_tail_of_evaluated_rounds() {
        let mut r = Recorder::new("t");
        r.push(rec(0, 50.0));
        r.push(rec(1, -1.0)); // round without eval
        r.push(rec(2, 10.0));
        r.push(rec(3, 20.0));
        assert!((r.final_wer(2) - 15.0).abs() < 1e-9);
        assert!((r.final_wer(10) - (80.0 / 3.0)).abs() < 1e-9);
    }

    #[test]
    fn comm_and_speed() {
        let mut r = Recorder::new("t");
        for i in 0..4 {
            r.push(rec(i, 10.0));
        }
        assert_eq!(r.total_comm_bytes(), 600);
        assert!((r.rounds_per_min() - 120.0).abs() < 1e-9); // 4 rounds / 2s
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut r = Recorder::new("t");
        r.push(rec(0, 12.5));
        let csv = r.to_csv();
        assert!(csv.starts_with("round,"));
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.contains("12.5"));
        // header and rows have the same column count (incl. cohort and
        // chaos-health columns)
        let cols = csv.lines().next().unwrap().split(',').count();
        assert_eq!(cols, 20);
        for line in csv.lines().skip(1) {
            assert_eq!(line.split(',').count(), cols, "{line}");
        }
    }

    #[test]
    fn sparse_columns_and_totals() {
        let mut r = Recorder::new("t");
        r.push(rec(0, 10.0));
        assert_eq!(r.sparsity(), 0.0); // nothing eligible → 0, not NaN
        let mut thin = rec(1, 10.0);
        thin.up_bytes_sparse_saved = 40;
        thin.sparse_selected = 25;
        thin.sparse_total = 100;
        thin.sparse_residual_sq = 9.0;
        r.push(thin);
        assert_eq!(r.total_up_bytes_sparse_saved(), 40);
        assert_eq!(r.total_sparse_selected(), 25);
        assert_eq!(r.total_sparse_total(), 100);
        assert!((r.sparsity() - 0.75).abs() < 1e-12);
        assert!((r.sparse_residual_norm() - 3.0).abs() < 1e-12);
        let csv = r.to_csv();
        assert!(csv.lines().next().unwrap().contains("up_bytes_sparse_saved"));
        assert!(csv.contains(",40,25,100,"), "{csv}");
    }

    #[test]
    fn completion_rate_tracks_cohort_failures() {
        let mut r = Recorder::new("t");
        assert!(r.mean_completion_rate().is_nan());
        r.push(rec(0, 10.0)); // 4/4
        let mut partial = rec(1, 10.0); // 2/4
        partial.completed = 2;
        partial.dropped = 1;
        partial.late = 1;
        partial.up_bytes_discarded = 10;
        r.push(partial);
        assert!((r.mean_completion_rate() - 0.75).abs() < 1e-9);
        assert!(r.to_csv().contains(",2,1,1,"));
    }

    #[test]
    fn chaos_health_columns_and_totals() {
        let mut r = Recorder::new("t");
        r.push(rec(0, 10.0));
        let mut stormy = rec(1, 10.0);
        stormy.completed = 2;
        stormy.crashed = 2;
        stormy.frames_rejected = 5;
        stormy.up_bytes_rejected = 123;
        r.push(stormy);
        assert_eq!(r.total_crashed(), 2);
        assert_eq!(r.total_frames_rejected(), 5);
        assert_eq!(r.total_up_bytes_rejected(), 123);
        let csv = r.to_csv();
        assert!(csv.lines().next().unwrap().contains("frames_rejected"));
        assert!(csv.contains(",2,5,123,"), "{csv}");
        // delta savings get their own column + total
        let mut lean = rec(2, 10.0);
        lean.up_bytes_delta_saved = 17;
        r.push(lean);
        assert_eq!(r.total_up_bytes_delta_saved(), 17);
        let csv = r.to_csv();
        assert!(csv.lines().next().unwrap().contains("up_bytes_delta_saved"));
        assert!(csv.contains(",17,"), "{csv}");
        // commit failures surface in the async CSV + total
        r.push_commit(commit(0, vec![2]));
        r.push_commit(commit(3, vec![2]));
        assert_eq!(r.total_commit_failures(), 3);
        let ccsv = r.commits_csv();
        assert!(ccsv.lines().next().unwrap().ends_with("commit_failures"));
        assert!(ccsv.lines().nth(2).unwrap().ends_with(",3"), "{ccsv}");
    }

    #[test]
    fn writes_files() {
        let dir = std::env::temp_dir().join(format!(
            "omc_rec_test_{}",
            std::process::id()
        ));
        let mut r = Recorder::new("demo");
        r.push(rec(0, 5.0));
        let (csv, js) = r.write(&dir).unwrap();
        assert!(csv.exists());
        assert!(js.exists());
        let parsed = crate::util::json::parse(
            &std::fs::read_to_string(&js).unwrap(),
        )
        .unwrap();
        assert_eq!(parsed.get("label").unwrap().as_str(), Some("demo"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn byte_splits_and_eval_curve() {
        let mut r = Recorder::new("t");
        r.push(rec(0, 12.0));
        r.push(rec(1, -1.0)); // no eval this round
        let mut late = rec(2, 8.0);
        late.up_bytes_discarded = 7;
        r.push(late);
        assert_eq!(r.total_down_bytes(), 300);
        assert_eq!(r.total_up_bytes(), 150);
        assert_eq!(r.total_up_bytes_discarded(), 7);
        assert_eq!(r.eval_wer_curve(), vec![(0, 12.0), (2, 8.0)]);
    }

    #[test]
    fn empty_recorder() {
        let r = Recorder::new("e");
        assert!(r.final_wer(3).is_nan());
        assert_eq!(r.rounds_per_min(), 0.0);
        assert!(!r.is_async());
        assert!(r.mean_staleness().is_nan());
        assert!(r.mean_buffer_occupancy().is_nan());
        assert!(r.final_virtual_time().is_nan());
        assert_eq!(r.commits_csv(), "");
    }

    fn commit(commit: usize, hist: Vec<usize>) -> CommitRecord {
        CommitRecord {
            commit,
            folded: hist.iter().sum(),
            mean_staleness: 0.0,
            staleness_hist: hist,
            mean_occupancy: 2.0 + commit as f64,
            window_events: 4,
            discarded_updates: commit,
            discarded_bytes: commit * 100,
            ring_bytes: 4096,
            virtual_time: 1.5 * (commit + 1) as f64,
            param_drift: 1e-3,
            commit_failures: commit as u32,
        }
    }

    #[test]
    fn async_readers_merge_commit_records() {
        let mut r = Recorder::new("a");
        r.push_commit(commit(0, vec![3, 1]));
        r.push_commit(commit(1, vec![1, 2, 1]));
        assert!(r.is_async());
        assert_eq!(r.staleness_histogram(), vec![4, 3, 1]);
        // (0*4 + 1*3 + 2*1) / 8
        assert!((r.mean_staleness() - 5.0 / 8.0).abs() < 1e-12);
        assert_eq!(r.max_staleness(), 2);
        // event-weighted occupancy: (2.0*4 + 3.0*4) / 8
        assert!((r.mean_buffer_occupancy() - 2.5).abs() < 1e-12);
        assert_eq!(r.total_discarded_updates(), 1);
        assert_eq!(r.total_discarded_bytes(), 100);
        assert_eq!(r.last_ring_bytes(), 4096);
        assert_eq!(r.final_virtual_time(), 3.0);
        let csv = r.commits_csv();
        assert!(csv.starts_with("commit,"));
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.contains("1|2|1"), "{csv}");
        // header and rows keep the same column count
        let cols = csv.lines().next().unwrap().split(',').count();
        for line in csv.lines().skip(1) {
            assert_eq!(line.split(',').count(), cols, "{line}");
        }
    }

    #[test]
    fn write_emits_commits_csv_only_for_async_runs() {
        let dir = std::env::temp_dir().join(format!(
            "omc_rec_async_test_{}",
            std::process::id()
        ));
        let mut r = Recorder::new("demo");
        r.push(rec(0, 5.0));
        r.write(&dir).unwrap();
        assert!(!dir.join("demo_commits.csv").exists());
        r.push_commit(commit(0, vec![4]));
        r.write(&dir).unwrap();
        let commits = std::fs::read_to_string(dir.join("demo_commits.csv")).unwrap();
        assert!(commits.starts_with("commit,"));
        std::fs::remove_dir_all(&dir).ok();
    }

    fn pop(attempts: u64) -> PopulationRoundStats {
        let mut p = PopulationRoundStats {
            registered: 1_000_000,
            edges: 4,
            ..Default::default()
        };
        p.sample.attempts = attempts;
        p.sample.duplicate_rejections = 1;
        p.sample.churn_rejections = 2;
        p.sample.wave_rejections = 3;
        p.sample.active_estimate = 400_000.0;
        p.sample.class_sampled[0] = 5;
        p.class_completed[0] = 4;
        p.edge.frames = 4;
        p.edge.up_bytes = 1024;
        p.edge.delta_saved = 128;
        p
    }

    #[test]
    fn population_totals_sum_per_round_records() {
        let mut r = Recorder::new("pop");
        assert!(!r.is_population());
        assert!(r.mean_active_estimate().is_nan());
        r.push_population(pop(10));
        r.push_population(pop(14));
        assert!(r.is_population());
        assert_eq!(r.total_sample_attempts(), 24);
        assert_eq!(r.total_duplicate_rejections(), 2);
        assert_eq!(r.total_churn_rejections(), 4);
        assert_eq!(r.total_wave_rejections(), 6);
        assert!((r.mean_active_estimate() - 400_000.0).abs() < 1e-9);
        assert_eq!(r.class_sampled_totals()[0], 10);
        assert_eq!(r.class_completed_totals()[0], 8);
        assert_eq!(r.total_edge_frames(), 8);
        assert_eq!(r.total_edge_up_bytes(), 2048);
        assert_eq!(r.total_edge_delta_saved(), 256);
    }

    #[test]
    fn populations_csv_keeps_column_count_and_joins_classes() {
        let mut r = Recorder::new("pop");
        assert_eq!(r.populations_csv(), "");
        r.push_population(pop(10));
        r.push_population(pop(14));
        let csv = r.populations_csv();
        assert!(csv.starts_with("round,registered,"), "{csv}");
        // class columns are |-joined, one slot per device class
        assert!(csv.contains("5|0|0|0"), "{csv}");
        assert!(csv.contains("4|0|0|0"), "{csv}");
        let cols = csv.lines().next().unwrap().split(',').count();
        for line in csv.lines().skip(1) {
            assert_eq!(line.split(',').count(), cols, "{line}");
        }
    }

    #[test]
    fn write_emits_population_csv_only_for_population_runs() {
        let dir = std::env::temp_dir().join(format!(
            "omc_rec_pop_test_{}",
            std::process::id()
        ));
        let mut r = Recorder::new("demo");
        r.push(rec(0, 5.0));
        r.write(&dir).unwrap();
        assert!(!dir.join("demo_population.csv").exists());
        r.push_population(pop(10));
        r.write(&dir).unwrap();
        let pop_csv =
            std::fs::read_to_string(dir.join("demo_population.csv")).unwrap();
        assert!(pop_csv.starts_with("round,"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn latency_histogram_quantiles_bracket_observations() {
        let mut h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.5), 0.0);
        for _ in 0..99 {
            h.record(1e-3);
        }
        h.record(1.0);
        assert_eq!(h.count(), 100);
        // bucket upper bound: within 2x above, never below
        let p50 = h.quantile(0.50);
        assert!((1e-3..=2e-3).contains(&p50), "{p50}");
        let p99 = h.quantile(0.99);
        assert!((1e-3..=2e-3).contains(&p99), "{p99}");
        assert!(h.quantile(1.0) >= 1.0);
        // degenerate observations land in bucket 0, not a panic
        h.record(f64::NAN);
        h.record(-5.0);
        h.record(1e9); // clamped into the top bucket
        assert_eq!(h.count(), 103);
    }

    #[test]
    fn latency_histogram_merge_matches_combined_recording() {
        let (mut a, mut b, mut both) =
            (LatencyHistogram::new(), LatencyHistogram::new(), LatencyHistogram::new());
        for i in 1..=50 {
            let s = i as f64 * 1e-4;
            a.record(s);
            both.record(s);
        }
        for i in 1..=50 {
            let s = i as f64 * 1e-2;
            b.record(s);
            both.record(s);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(a.quantile(q), both.quantile(q), "q={q}");
        }
    }

    #[test]
    fn serve_record_round_trips_report_and_json() {
        use crate::fl::serve::ServeReport;
        use crate::util::arena::ArenaStats;
        let rep = ServeReport {
            commits: 8,
            workers: 4,
            queue_depth: 12,
            wall_s: 2.0,
            down_bytes: 6000,
            up_bytes: 2000,
            uplinks: 32,
            uplink_p50_s: 0.002,
            uplink_p99_s: 0.004,
            queue_peak_depth: 7,
            queue_rejected_frames: 3,
            queue_rejected_bytes: 150,
            probe_rejected_frames: 8,
            frame_arena: ArenaStats {
                acquires: 40,
                fresh: 6,
                recycled: 34,
            },
            scratch_arena: ArenaStats {
                acquires: 4,
                fresh: 4,
                recycled: 0,
            },
        };
        let rec = ServeRecord::from_report(&rep);
        assert_eq!(rec.commits_per_sec, 4.0);
        assert_eq!(rec.bytes_per_sec, 4000.0);
        // probe rejections fold into the accounting total
        assert_eq!(rec.queue_rejected_frames, 11);
        assert_eq!(rec.arena_acquires, 44);
        assert_eq!(rec.arena_fresh, 10);
        assert_eq!(rec.arena_recycled, 34);
        let js = rec.to_json().to_string();
        for key in [
            "commits_per_sec",
            "uplink_p99_s",
            "queue_rejected_frames",
            "arena_recycled",
        ] {
            assert!(js.contains(key), "{js}");
        }
    }

    #[test]
    fn csv_stream_appends_and_survives_flush_boundaries() {
        let dir = std::env::temp_dir().join(format!(
            "omc_csv_stream_test_{}",
            std::process::id()
        ));
        let path = dir.join("serve_commits.csv");
        let mut s = CsvStream::create(&path, "commit,folded").unwrap();
        s.append("0,4").unwrap();
        s.flush().unwrap();
        // rows up to the last flush are durable while the stream is open
        let on_disk = std::fs::read_to_string(&path).unwrap();
        assert_eq!(on_disk, "commit,folded\n0,4\n");
        s.append("1,5").unwrap();
        s.flush().unwrap();
        assert_eq!(s.path(), path.as_path());
        drop(s);
        let on_disk = std::fs::read_to_string(&path).unwrap();
        assert_eq!(on_disk, "commit,folded\n0,4\n1,5\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}
