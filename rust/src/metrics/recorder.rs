//! Round-log recording: per-round metrics to CSV + a JSON summary, the raw
//! material for EXPERIMENTS.md and the figure-reproduction examples.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

/// One federated round's metrics.
#[derive(Clone, Debug, Default)]
pub struct RoundRecord {
    pub round: usize,
    pub train_loss: f64,
    pub eval_loss: f64,
    pub eval_wer: f64,
    /// bytes server->clients this round
    pub down_bytes: usize,
    /// bytes clients->server this round
    pub up_bytes: usize,
    /// uplink bytes that arrived past the reporting deadline (spent but
    /// excluded from aggregation; subset of `up_bytes`)
    pub up_bytes_discarded: usize,
    /// clients sampled into the cohort
    pub sampled: usize,
    /// clients whose update was aggregated
    pub completed: usize,
    /// clients that dropped after the downlink
    pub dropped: usize,
    /// clients that reported after the deadline
    pub late: usize,
    pub round_seconds: f64,
}

/// Collects round records and writes them out.
#[derive(Debug, Default)]
pub struct Recorder {
    pub records: Vec<RoundRecord>,
    pub label: String,
}

impl Recorder {
    pub fn new(label: &str) -> Self {
        Self {
            records: Vec::new(),
            label: label.to_string(),
        }
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.records.push(r);
    }

    pub fn last(&self) -> Option<&RoundRecord> {
        self.records.last()
    }

    /// Mean WER over the final `k` evaluated rounds (the number the tables
    /// report; evaluation cadence may skip rounds, so filter on eval_wer
    /// having been set).
    pub fn final_wer(&self, k: usize) -> f64 {
        let evals: Vec<f64> = self
            .records
            .iter()
            .filter(|r| r.eval_wer >= 0.0 && r.eval_loss > 0.0)
            .map(|r| r.eval_wer)
            .collect();
        if evals.is_empty() {
            return f64::NAN;
        }
        let tail = &evals[evals.len().saturating_sub(k)..];
        tail.iter().sum::<f64>() / tail.len() as f64
    }

    /// Total communication (both directions) in bytes.
    pub fn total_comm_bytes(&self) -> usize {
        self.records
            .iter()
            .map(|r| r.down_bytes + r.up_bytes)
            .sum()
    }

    /// Total server→client bytes across the run.
    pub fn total_down_bytes(&self) -> usize {
        self.records.iter().map(|r| r.down_bytes).sum()
    }

    /// Total client→server bytes across the run.
    pub fn total_up_bytes(&self) -> usize {
        self.records.iter().map(|r| r.up_bytes).sum()
    }

    /// Total uplink bytes spent by past-deadline clients (subset of
    /// [`total_up_bytes`](Self::total_up_bytes)).
    pub fn total_up_bytes_discarded(&self) -> usize {
        self.records.iter().map(|r| r.up_bytes_discarded).sum()
    }

    /// `(round, WER)` for every evaluated round, in order — the figure
    /// curves, and the deterministic per-cell sweep summaries.
    pub fn eval_wer_curve(&self) -> Vec<(usize, f64)> {
        self.records
            .iter()
            .filter(|r| r.eval_wer >= 0.0 && r.eval_loss > 0.0)
            .map(|r| (r.round, r.eval_wer))
            .collect()
    }

    /// Rounds per minute over the whole run (the tables' Speed column).
    pub fn rounds_per_min(&self) -> f64 {
        let secs: f64 = self.records.iter().map(|r| r.round_seconds).sum();
        if secs == 0.0 {
            return 0.0;
        }
        60.0 * self.records.len() as f64 / secs
    }

    /// Mean fraction of sampled clients whose update was aggregated
    /// (1.0 for ideal cohorts; NaN when nothing was recorded).
    pub fn mean_completion_rate(&self) -> f64 {
        if self.records.is_empty() {
            return f64::NAN;
        }
        let rates: f64 = self
            .records
            .iter()
            .map(|r| r.completed as f64 / r.sampled.max(1) as f64)
            .sum();
        rates / self.records.len() as f64
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "round,train_loss,eval_loss,eval_wer,down_bytes,up_bytes,\
             up_bytes_discarded,sampled,completed,dropped,late,round_seconds\n",
        );
        for r in &self.records {
            out.push_str(&format!(
                "{},{:.6},{:.6},{:.4},{},{},{},{},{},{},{},{:.6}\n",
                r.round,
                r.train_loss,
                r.eval_loss,
                r.eval_wer,
                r.down_bytes,
                r.up_bytes,
                r.up_bytes_discarded,
                r.sampled,
                r.completed,
                r.dropped,
                r.late,
                r.round_seconds
            ));
        }
        out
    }

    pub fn summary_json(&self) -> Json {
        json::obj(vec![
            ("label", json::s(&self.label)),
            ("rounds", json::num(self.records.len() as f64)),
            ("final_wer", json::num(self.final_wer(3))),
            (
                "final_train_loss",
                json::num(self.last().map(|r| r.train_loss).unwrap_or(f64::NAN)),
            ),
            (
                "total_comm_bytes",
                json::num(self.total_comm_bytes() as f64),
            ),
            (
                "mean_completion_rate",
                json::num(self.mean_completion_rate()),
            ),
            ("rounds_per_min", json::num(self.rounds_per_min())),
        ])
    }

    /// Write `<dir>/<label>.csv` and `<dir>/<label>.json`.
    pub fn write(&self, dir: &Path) -> Result<(PathBuf, PathBuf)> {
        fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let csv_path = dir.join(format!("{}.csv", self.label));
        let mut f = fs::File::create(&csv_path)?;
        f.write_all(self.to_csv().as_bytes())?;
        let json_path = dir.join(format!("{}.json", self.label));
        let mut f = fs::File::create(&json_path)?;
        f.write_all(self.summary_json().to_string().as_bytes())?;
        Ok((csv_path, json_path))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, wer: f64) -> RoundRecord {
        RoundRecord {
            round,
            train_loss: 1.0,
            eval_loss: if wer >= 0.0 { 0.5 } else { 0.0 },
            eval_wer: wer,
            down_bytes: 100,
            up_bytes: 50,
            up_bytes_discarded: 0,
            sampled: 4,
            completed: 4,
            dropped: 0,
            late: 0,
            round_seconds: 0.5,
        }
    }

    #[test]
    fn final_wer_averages_tail_of_evaluated_rounds() {
        let mut r = Recorder::new("t");
        r.push(rec(0, 50.0));
        r.push(rec(1, -1.0)); // round without eval
        r.push(rec(2, 10.0));
        r.push(rec(3, 20.0));
        assert!((r.final_wer(2) - 15.0).abs() < 1e-9);
        assert!((r.final_wer(10) - (80.0 / 3.0)).abs() < 1e-9);
    }

    #[test]
    fn comm_and_speed() {
        let mut r = Recorder::new("t");
        for i in 0..4 {
            r.push(rec(i, 10.0));
        }
        assert_eq!(r.total_comm_bytes(), 600);
        assert!((r.rounds_per_min() - 120.0).abs() < 1e-9); // 4 rounds / 2s
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut r = Recorder::new("t");
        r.push(rec(0, 12.5));
        let csv = r.to_csv();
        assert!(csv.starts_with("round,"));
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.contains("12.5"));
        // header and rows have the same column count (incl. cohort columns)
        let cols = csv.lines().next().unwrap().split(',').count();
        assert_eq!(cols, 12);
        for line in csv.lines().skip(1) {
            assert_eq!(line.split(',').count(), cols, "{line}");
        }
    }

    #[test]
    fn completion_rate_tracks_cohort_failures() {
        let mut r = Recorder::new("t");
        assert!(r.mean_completion_rate().is_nan());
        r.push(rec(0, 10.0)); // 4/4
        let mut partial = rec(1, 10.0); // 2/4
        partial.completed = 2;
        partial.dropped = 1;
        partial.late = 1;
        partial.up_bytes_discarded = 10;
        r.push(partial);
        assert!((r.mean_completion_rate() - 0.75).abs() < 1e-9);
        assert!(r.to_csv().contains(",2,1,1,"));
    }

    #[test]
    fn writes_files() {
        let dir = std::env::temp_dir().join(format!(
            "omc_rec_test_{}",
            std::process::id()
        ));
        let mut r = Recorder::new("demo");
        r.push(rec(0, 5.0));
        let (csv, js) = r.write(&dir).unwrap();
        assert!(csv.exists());
        assert!(js.exists());
        let parsed = crate::util::json::parse(
            &std::fs::read_to_string(&js).unwrap(),
        )
        .unwrap();
        assert_eq!(parsed.get("label").unwrap().as_str(), Some("demo"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn byte_splits_and_eval_curve() {
        let mut r = Recorder::new("t");
        r.push(rec(0, 12.0));
        r.push(rec(1, -1.0)); // no eval this round
        let mut late = rec(2, 8.0);
        late.up_bytes_discarded = 7;
        r.push(late);
        assert_eq!(r.total_down_bytes(), 300);
        assert_eq!(r.total_up_bytes(), 150);
        assert_eq!(r.total_up_bytes_discarded(), 7);
        assert_eq!(r.eval_wer_curve(), vec![(0, 12.0), (2, 8.0)]);
    }

    #[test]
    fn empty_recorder() {
        let r = Recorder::new("e");
        assert!(r.final_wer(3).is_nan());
        assert_eq!(r.rounds_per_min(), 0.0);
    }
}
