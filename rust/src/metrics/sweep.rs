//! Deterministic sweep summaries — the byte-stable JSON the CI golden
//! gate diffs.
//!
//! A sweep produces one JSON document per cell plus one consolidated
//! `sweep_summary.json`. Every field in these documents is a pure function
//! of `(cell config, cell seed)`: **no wall-clock numbers** — timing goes
//! to the separate, non-golden `sweep_timing.json` written by
//! `coordinator::sweep`. Combined with the canonical writer in
//! [`crate::util::json`] (sorted keys, shortest-round-trip floats,
//! non-finite → `null`), two runs of the same sweep emit byte-identical
//! summaries, which is what lets CI gate on `cmp` and a committed golden.
//!
//! Round-trip stability: a summary parsed back through
//! [`crate::util::json::parse`] and re-serialized is byte-identical to the
//! original (the writer's number formatting is idempotent over its own
//! output). `--resume` relies on this to splice previously-written cell
//! files into a fresh consolidated summary without breaking byte equality.

use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::experiment::RunSummary;
use crate::metrics::recorder::Recorder;
use crate::util::json::{self, Json};

/// Schema version stamped into every summary (bump on field changes so
/// stale goldens fail loudly instead of diffing field-by-field).
/// v2: `async_mode` on every cell + the `async` metrics object on async
/// cells (staleness histogram, buffer occupancy, discarded bytes, ring
/// memory, virtual time — all deterministic in `(config, seed)`).
/// v3: `integrity`/`chaos_enabled` flags plus the wire-health counters
/// (`crashed`, `frames_rejected`, `up_bytes_rejected`; `commit_failures`
/// in the async object) — the CI chaos gate greps these.
/// v4: `delta_enabled` flag + `up_bytes_delta_saved` counter (bytes the
/// lossless delta wire stage shaved off verbatim uplink framing) — the CI
/// delta-determinism gate greps these.
/// v5: `population_mode` flag on every cell + the `population` metrics
/// object on population cells (registered fleet size, edge count, sampler
/// attempt/rejection counters, analytic active estimate, per-device-class
/// sampled/completed counts, edge→root frame/byte/delta counters — all
/// deterministic in `(config, seed)`) — the CI scale gate greps these.
/// v6: `sparse_mode` label + the sparse uplink counters
/// (`up_bytes_sparse_saved`, `sparsity`, `sparse_residual_norm` — all
/// deterministic in `(config, seed)`) — the CI sparse gate greps these.
pub const SWEEP_SCHEMA_VERSION: usize = 6;

/// Build the deterministic summary document for one finished cell.
///
/// `fingerprint` is the cell's config hash (hex) — `--resume` verifies it
/// before trusting an on-disk summary.
pub fn cell_summary(
    index: usize,
    cfg: &ExperimentConfig,
    fingerprint: &str,
    rec: &Recorder,
    run: &RunSummary,
) -> Json {
    let curve: Vec<Json> = rec
        .eval_wer_curve()
        .into_iter()
        .map(|(r, w)| Json::Arr(vec![json::num(r as f64), json::num(w)]))
        .collect();
    let mut pairs = vec![
        ("cell_index", json::num(index as f64)),
        ("config_hash", json::s(fingerprint)),
        ("label", json::s(&cfg.name)),
        // derived seeds are full u64s (hash_seed outputs exceed 2^53, the
        // largest exactly-representable f64 integer) — a string keeps the
        // recorded seed exact so a cell can be reproduced from its summary
        ("seed", json::s(&cfg.seed.to_string())),
        ("model_dir", json::s(&cfg.model_dir.display().to_string())),
        ("format", json::s(&cfg.omc.format.to_string())),
        ("pvt", Json::Bool(cfg.omc.use_pvt)),
        ("weights_only", Json::Bool(cfg.omc.weights_only)),
        ("fraction", json::num(cfg.omc.fraction)),
        ("partition", json::s(&format!("{}", cfg.partition))),
        ("domain", json::num(cfg.domain as f64)),
        ("num_clients", json::num(cfg.num_clients as f64)),
        (
            "clients_per_round",
            json::num(cfg.clients_per_round as f64),
        ),
        ("local_steps", json::num(cfg.local_steps as f64)),
        ("rounds", json::num(rec.records.len() as f64)),
        ("cohort_ideal", Json::Bool(cfg.cohort.is_ideal())),
        ("final_wer", json::num(run.final_wer)),
        ("final_train_loss", json::num(run.final_loss)),
        (
            "param_memory_bytes",
            json::num(run.param_memory_bytes as f64),
        ),
        ("memory_ratio", json::num(run.memory_ratio)),
        (
            "total_down_bytes",
            json::num(rec.total_down_bytes() as f64),
        ),
        ("total_up_bytes", json::num(rec.total_up_bytes() as f64)),
        (
            "total_up_bytes_discarded",
            json::num(rec.total_up_bytes_discarded() as f64),
        ),
        (
            "mean_completion_rate",
            json::num(rec.mean_completion_rate()),
        ),
        ("eval_wer_curve", Json::Arr(curve)),
        ("async_mode", Json::Bool(cfg.async_cfg.enabled)),
        ("integrity", Json::Bool(cfg.omc.integrity)),
        ("chaos_enabled", Json::Bool(!cfg.chaos.is_off())),
        ("crashed", json::num(rec.total_crashed() as f64)),
        (
            "frames_rejected",
            json::num(rec.total_frames_rejected() as f64),
        ),
        (
            "up_bytes_rejected",
            json::num(rec.total_up_bytes_rejected() as f64),
        ),
        ("delta_enabled", Json::Bool(cfg.delta.enabled)),
        (
            "up_bytes_delta_saved",
            json::num(rec.total_up_bytes_delta_saved() as f64),
        ),
        (
            "sparse_mode",
            json::s(if cfg.sparse.enabled {
                cfg.sparse.mode.name()
            } else {
                "off"
            }),
        ),
        (
            "up_bytes_sparse_saved",
            json::num(rec.total_up_bytes_sparse_saved() as f64),
        ),
        ("sparsity", json::num(rec.sparsity())),
        (
            "sparse_residual_norm",
            json::num(rec.sparse_residual_norm()),
        ),
        ("population_mode", Json::Bool(cfg.population.enabled)),
    ];
    if cfg.population.enabled {
        let sampled = rec.class_sampled_totals();
        let completed = rec.class_completed_totals();
        let arr = |xs: &[u64]| {
            Json::Arr(xs.iter().map(|&n| json::num(n as f64)).collect())
        };
        // per-class completion rate; a class nobody sampled reads as null
        // (the canonical writer maps NaN to null deterministically)
        let rates: Vec<Json> = sampled
            .iter()
            .zip(&completed)
            .map(|(&s, &c)| json::num(c as f64 / s as f64))
            .collect();
        pairs.push((
            "population",
            json::obj(vec![
                (
                    "registered",
                    json::num(cfg.population.registered as f64),
                ),
                ("edges", json::num(cfg.population.edges as f64)),
                (
                    "sample_attempts",
                    json::num(rec.total_sample_attempts() as f64),
                ),
                (
                    "duplicate_rejections",
                    json::num(rec.total_duplicate_rejections() as f64),
                ),
                (
                    "churn_rejections",
                    json::num(rec.total_churn_rejections() as f64),
                ),
                (
                    "wave_rejections",
                    json::num(rec.total_wave_rejections() as f64),
                ),
                (
                    "mean_active_estimate",
                    json::num(rec.mean_active_estimate()),
                ),
                ("class_sampled", arr(&sampled)),
                ("class_completed", arr(&completed)),
                ("class_completion_rate", Json::Arr(rates)),
                (
                    "edge_frames",
                    json::num(rec.total_edge_frames() as f64),
                ),
                (
                    "edge_up_bytes",
                    json::num(rec.total_edge_up_bytes() as f64),
                ),
                (
                    "edge_delta_saved",
                    json::num(rec.total_edge_delta_saved() as f64),
                ),
            ]),
        ));
    }
    if cfg.async_cfg.enabled {
        let a = cfg.async_cfg.resolved(cfg.clients_per_round);
        // merge the histogram once; mean/max derive from it directly
        // instead of re-merging through the Recorder readers
        let merged = rec.staleness_histogram();
        let folded: usize = merged.iter().sum();
        let mean_staleness = if folded > 0 {
            merged
                .iter()
                .enumerate()
                .map(|(s, &n)| s * n)
                .sum::<usize>() as f64
                / folded as f64
        } else {
            f64::NAN
        };
        let max_staleness =
            merged.iter().rposition(|&n| n > 0).unwrap_or(0);
        let hist: Vec<Json> = merged
            .into_iter()
            .map(|n| json::num(n as f64))
            .collect();
        pairs.push((
            "async",
            json::obj(vec![
                ("concurrency", json::num(a.concurrency as f64)),
                ("buffer_k", json::num(a.buffer_k as f64)),
                ("policy", json::s(&a.policy.to_string())),
                (
                    "max_staleness",
                    if a.max_staleness == usize::MAX {
                        Json::Null
                    } else {
                        json::num(a.max_staleness as f64)
                    },
                ),
                ("snapshot_ring", json::num(a.snapshot_ring as f64)),
                ("commits", json::num(rec.commits.len() as f64)),
                ("mean_staleness", json::num(mean_staleness)),
                (
                    "max_observed_staleness",
                    json::num(max_staleness as f64),
                ),
                ("staleness_hist", Json::Arr(hist)),
                (
                    "mean_buffer_occupancy",
                    json::num(rec.mean_buffer_occupancy()),
                ),
                (
                    "discarded_updates",
                    json::num(rec.total_discarded_updates() as f64),
                ),
                (
                    "discarded_update_bytes",
                    json::num(rec.total_discarded_bytes() as f64),
                ),
                (
                    "snapshot_ring_bytes",
                    json::num(rec.last_ring_bytes() as f64),
                ),
                (
                    "final_virtual_time",
                    json::num(rec.final_virtual_time()),
                ),
                (
                    "commit_failures",
                    json::num(rec.total_commit_failures() as f64),
                ),
            ]),
        ));
    }
    json::obj(pairs)
}

/// Build the consolidated sweep summary from per-cell documents (in cell
/// order — the order is part of the byte contract).
pub fn sweep_summary(name: &str, seed: u64, cells: Vec<Json>) -> Json {
    json::obj(vec![
        ("schema_version", json::num(SWEEP_SCHEMA_VERSION as f64)),
        ("sweep", json::s(name)),
        // string for the same exactness reason as the per-cell seeds
        ("seed", json::s(&seed.to_string())),
        ("num_cells", json::num(cells.len() as f64)),
        ("cells", Json::Arr(cells)),
    ])
}

/// Convenience readers for consumers of a cell document (the example
/// wrappers print their tables from these instead of live `RunSummary`
/// values so fresh and `--resume` runs render identically).
pub struct CellView<'a>(pub &'a Json);

impl<'a> CellView<'a> {
    fn f(&self, key: &str) -> f64 {
        self.0.get(key).and_then(|v| v.as_f64()).unwrap_or(f64::NAN)
    }

    pub fn label(&self) -> &'a str {
        self.0.get("label").and_then(|v| v.as_str()).unwrap_or("?")
    }

    pub fn final_wer(&self) -> f64 {
        self.f("final_wer")
    }

    pub fn final_train_loss(&self) -> f64 {
        self.f("final_train_loss")
    }

    pub fn param_memory_bytes(&self) -> usize {
        self.f("param_memory_bytes") as usize
    }

    pub fn memory_ratio(&self) -> f64 {
        self.f("memory_ratio")
    }

    pub fn rounds(&self) -> usize {
        self.f("rounds") as usize
    }

    pub fn total_comm_bytes(&self) -> f64 {
        self.f("total_down_bytes") + self.f("total_up_bytes")
    }

    /// `(round, WER)` pairs of the evaluated rounds.
    pub fn eval_wer_curve(&self) -> Vec<(usize, f64)> {
        let Some(arr) = self.0.get("eval_wer_curve").and_then(|v| v.as_arr())
        else {
            return Vec::new();
        };
        arr.iter()
            .filter_map(|p| {
                let pair = p.as_arr()?;
                Some((pair.first()?.as_f64()? as usize, pair.get(1)?.as_f64()?))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::recorder::RoundRecord;
    use std::path::Path;

    fn sample_cell() -> Json {
        let cfg = ExperimentConfig::default_with("cell_a", Path::new("native:tiny"));
        let mut rec = Recorder::new("cell_a");
        rec.push(RoundRecord {
            round: 0,
            train_loss: 1.5,
            eval_loss: 0.5,
            eval_wer: 42.25,
            down_bytes: 1000,
            up_bytes: 900,
            up_bytes_discarded: 10,
            sampled: 4,
            completed: 4,
            dropped: 0,
            late: 0,
            crashed: 0,
            frames_rejected: 0,
            up_bytes_rejected: 0,
            up_bytes_delta_saved: 0,
            up_bytes_sparse_saved: 0,
            sparse_selected: 0,
            sparse_total: 0,
            sparse_residual_sq: 0.0,
            round_seconds: 0.123, // must never appear in the summary
        });
        let run = RunSummary {
            label: "cell_a".into(),
            final_wer: 42.25,
            final_loss: 1.5,
            param_memory_bytes: 6400,
            memory_ratio: 1.0,
            comm_bytes_per_round: 1900.0,
            rounds_per_min: 480.0, // timing — must never appear
            rounds: 1,
        };
        cell_summary(0, &cfg, "00ff00ff00ff00ff", &rec, &run)
    }

    #[test]
    fn cell_summary_has_no_timing_fields() {
        let text = sample_cell().to_string();
        assert!(!text.contains("seconds"), "{text}");
        assert!(!text.contains("rounds_per_min"), "{text}");
        assert!(text.contains("\"config_hash\":\"00ff00ff00ff00ff\""));
        assert!(text.contains("\"eval_wer_curve\":[[0,42.25]]"));
    }

    #[test]
    fn summary_roundtrip_is_byte_identical() {
        // --resume splices parsed cell files back into the consolidated
        // summary; parse∘write must be the identity on our own output
        let doc = sweep_summary("smoke", 42, vec![sample_cell()]);
        let bytes = doc.to_string();
        let reparsed = json::parse(&bytes).unwrap();
        assert_eq!(reparsed.to_string(), bytes);
    }

    #[test]
    fn cell_view_reads_back_fields() {
        let cell = sample_cell();
        let v = CellView(&cell);
        assert_eq!(v.label(), "cell_a");
        assert_eq!(v.final_wer(), 42.25);
        assert_eq!(v.param_memory_bytes(), 6400);
        assert_eq!(v.rounds(), 1);
        assert_eq!(v.total_comm_bytes(), 1900.0);
        assert_eq!(v.eval_wer_curve(), vec![(0, 42.25)]);
    }

    #[test]
    fn derived_u64_seeds_are_recorded_exactly() {
        let mut cfg =
            ExperimentConfig::default_with("s", Path::new("native:tiny"));
        cfg.seed = u64::MAX - 7; // > 2^53: would round through f64
        let rec = Recorder::new("s");
        let run = RunSummary {
            label: "s".into(),
            final_wer: 0.0,
            final_loss: 0.0,
            param_memory_bytes: 0,
            memory_ratio: 0.0,
            comm_bytes_per_round: 0.0,
            rounds_per_min: 0.0,
            rounds: 0,
        };
        let cell = cell_summary(0, &cfg, "ff", &rec, &run);
        assert_eq!(
            cell.get("seed").and_then(|v| v.as_str()),
            Some((u64::MAX - 7).to_string().as_str())
        );
        let sweep = sweep_summary("x", u64::MAX - 7, vec![cell]);
        assert_eq!(
            sweep.get("seed").and_then(|v| v.as_str()),
            Some((u64::MAX - 7).to_string().as_str())
        );
    }

    #[test]
    fn async_cells_carry_deterministic_async_metrics() {
        use crate::metrics::recorder::CommitRecord;
        let mut cfg =
            ExperimentConfig::default_with("a", Path::new("native:tiny"));
        cfg.async_cfg.enabled = true;
        cfg.async_cfg.buffer_k = 3;
        let mut rec = Recorder::new("a");
        rec.push_commit(CommitRecord {
            commit: 0,
            folded: 3,
            mean_staleness: 0.5,
            staleness_hist: vec![2, 1],
            mean_occupancy: 1.5,
            window_events: 4,
            discarded_updates: 1,
            discarded_bytes: 99,
            ring_bytes: 2048,
            virtual_time: 2.25,
            param_drift: 1e-3,
            commit_failures: 2,
        });
        let run = RunSummary {
            label: "a".into(),
            final_wer: 10.0,
            final_loss: 1.0,
            param_memory_bytes: 100,
            memory_ratio: 0.5,
            comm_bytes_per_round: 10.0,
            rounds_per_min: 1.0,
            rounds: 1,
        };
        let cell = cell_summary(0, &cfg, "ff", &rec, &run);
        let text = cell.to_string();
        assert!(text.contains("\"async_mode\":true"));
        assert!(text.contains("\"staleness_hist\":[2,1]"));
        assert!(text.contains("\"discarded_update_bytes\":99"));
        assert!(text.contains("\"snapshot_ring_bytes\":2048"));
        assert!(text.contains("\"final_virtual_time\":2.25"));
        // unlimited staleness records as null, and no timing leaks in
        assert!(text.contains("\"max_staleness\":null"));
        assert!(!text.contains("seconds"), "{text}");
        // buffer_k resolved against the experiment's clients_per_round
        assert!(text.contains("\"buffer_k\":3"));
        // a sync cell carries the flag but no async object
        let sync = sample_cell().to_string();
        assert!(sync.contains("\"async_mode\":false"));
        assert!(!sync.contains("\"staleness_hist\""));
        // round-trip stability holds with the new fields
        let reparsed = json::parse(&text).unwrap();
        assert_eq!(reparsed.to_string(), text);
    }

    #[test]
    fn chaos_cells_carry_wire_health_counters() {
        let mut cfg =
            ExperimentConfig::default_with("c", Path::new("native:tiny"));
        cfg.omc.integrity = true;
        cfg.chaos.enabled = true;
        cfg.chaos.bitflip_prob = 0.2;
        let mut rec = Recorder::new("c");
        let mut r = RoundRecord {
            round: 0,
            train_loss: 1.0,
            eval_loss: 0.5,
            eval_wer: 20.0,
            down_bytes: 100,
            up_bytes: 90,
            up_bytes_discarded: 0,
            sampled: 4,
            completed: 3,
            dropped: 0,
            late: 0,
            crashed: 1,
            frames_rejected: 4,
            up_bytes_rejected: 77,
            up_bytes_delta_saved: 0,
            up_bytes_sparse_saved: 0,
            sparse_selected: 0,
            sparse_total: 0,
            sparse_residual_sq: 0.0,
            round_seconds: 0.1,
        };
        rec.push(r.clone());
        r.round = 1;
        r.frames_rejected = 2;
        r.up_bytes_rejected = 33;
        r.crashed = 0;
        rec.push(r);
        let run = RunSummary {
            label: "c".into(),
            final_wer: 20.0,
            final_loss: 1.0,
            param_memory_bytes: 100,
            memory_ratio: 0.5,
            comm_bytes_per_round: 10.0,
            rounds_per_min: 1.0,
            rounds: 2,
        };
        let cell = cell_summary(0, &cfg, "ff", &rec, &run);
        let text = cell.to_string();
        assert!(text.contains("\"integrity\":true"));
        assert!(text.contains("\"chaos_enabled\":true"));
        assert!(text.contains("\"crashed\":1"));
        assert!(text.contains("\"frames_rejected\":6"));
        assert!(text.contains("\"up_bytes_rejected\":110"));
        // clean cells keep the counters at zero but still present — the
        // CI grep gate relies on the keys existing either way
        let clean = sample_cell().to_string();
        assert!(clean.contains("\"chaos_enabled\":false"));
        assert!(clean.contains("\"frames_rejected\":0"));
        // round-trip stability holds with the new fields
        let reparsed = json::parse(&text).unwrap();
        assert_eq!(reparsed.to_string(), text);
    }

    #[test]
    fn delta_cells_carry_savings_counter() {
        let mut cfg =
            ExperimentConfig::default_with("d", Path::new("native:tiny"));
        cfg.omc.integrity = true;
        cfg.delta.enabled = true;
        let mut rec = Recorder::new("d");
        let mut r = RoundRecord {
            round: 0,
            train_loss: 1.0,
            eval_loss: 0.5,
            eval_wer: 20.0,
            down_bytes: 100,
            up_bytes: 60,
            up_bytes_discarded: 0,
            sampled: 4,
            completed: 4,
            dropped: 0,
            late: 0,
            crashed: 0,
            frames_rejected: 0,
            up_bytes_rejected: 0,
            up_bytes_delta_saved: 30,
            up_bytes_sparse_saved: 0,
            sparse_selected: 0,
            sparse_total: 0,
            sparse_residual_sq: 0.0,
            round_seconds: 0.1,
        };
        rec.push(r.clone());
        r.round = 1;
        r.up_bytes_delta_saved = 12;
        rec.push(r);
        let run = RunSummary {
            label: "d".into(),
            final_wer: 20.0,
            final_loss: 1.0,
            param_memory_bytes: 100,
            memory_ratio: 0.5,
            comm_bytes_per_round: 10.0,
            rounds_per_min: 1.0,
            rounds: 2,
        };
        let cell = cell_summary(0, &cfg, "ff", &rec, &run);
        let text = cell.to_string();
        assert!(text.contains("\"delta_enabled\":true"));
        assert!(text.contains("\"up_bytes_delta_saved\":42"));
        // verbatim cells keep the keys (the CI grep gate relies on them)
        let plain = sample_cell().to_string();
        assert!(plain.contains("\"delta_enabled\":false"));
        assert!(plain.contains("\"up_bytes_delta_saved\":0"));
        // round-trip stability holds with the new fields
        let reparsed = json::parse(&text).unwrap();
        assert_eq!(reparsed.to_string(), text);
    }

    #[test]
    fn sparse_cells_carry_selection_metrics() {
        let mut cfg =
            ExperimentConfig::default_with("sp", Path::new("native:tiny"));
        cfg.omc.integrity = true;
        cfg.sparse.enabled = true;
        let mut rec = Recorder::new("sp");
        let mut r = RoundRecord {
            round: 0,
            train_loss: 1.0,
            eval_loss: 0.5,
            eval_wer: 20.0,
            down_bytes: 100,
            up_bytes: 50,
            up_bytes_discarded: 0,
            sampled: 4,
            completed: 4,
            dropped: 0,
            late: 0,
            crashed: 0,
            frames_rejected: 0,
            up_bytes_rejected: 0,
            up_bytes_delta_saved: 0,
            up_bytes_sparse_saved: 40,
            sparse_selected: 25,
            sparse_total: 100,
            sparse_residual_sq: 16.0,
            round_seconds: 0.1,
        };
        rec.push(r.clone());
        r.round = 1;
        r.up_bytes_sparse_saved = 10;
        r.sparse_selected = 75;
        r.sparse_total = 100;
        r.sparse_residual_sq = 9.0;
        rec.push(r);
        let run = RunSummary {
            label: "sp".into(),
            final_wer: 20.0,
            final_loss: 1.0,
            param_memory_bytes: 100,
            memory_ratio: 0.5,
            comm_bytes_per_round: 10.0,
            rounds_per_min: 1.0,
            rounds: 2,
        };
        let cell = cell_summary(0, &cfg, "ff", &rec, &run);
        let text = cell.to_string();
        assert!(text.contains("\"sparse_mode\":\"topk\""), "{text}");
        assert!(text.contains("\"up_bytes_sparse_saved\":50"));
        // 1 - 100/200
        assert!(text.contains("\"sparsity\":0.5"), "{text}");
        // sqrt(16 + 9) = 5
        assert!(text.contains("\"sparse_residual_norm\":5"), "{text}");
        // dense cells keep the keys with the "off" label and zero values
        // (the CI sparse gate greps the keys either way)
        let plain = sample_cell().to_string();
        assert!(plain.contains("\"sparse_mode\":\"off\""));
        assert!(plain.contains("\"up_bytes_sparse_saved\":0"));
        assert!(plain.contains("\"sparsity\":0"));
        // round-trip stability holds with the new fields
        let reparsed = json::parse(&text).unwrap();
        assert_eq!(reparsed.to_string(), text);
    }

    #[test]
    fn population_cells_carry_scale_metrics() {
        use crate::fl::population::PopulationRoundStats;
        let mut cfg =
            ExperimentConfig::default_with("p", Path::new("native:tiny"));
        cfg.population.enabled = true;
        cfg.population.registered = 1_000_000;
        cfg.population.edges = 4;
        let mut rec = Recorder::new("p");
        let mut p = PopulationRoundStats {
            registered: 1_000_000,
            edges: 4,
            ..Default::default()
        };
        p.sample.attempts = 12;
        p.sample.duplicate_rejections = 1;
        p.sample.churn_rejections = 2;
        p.sample.wave_rejections = 3;
        p.sample.active_estimate = 250_000.0;
        p.sample.class_sampled = [4, 2, 1, 1];
        p.class_completed = [4, 1, 0, 0];
        p.edge.frames = 4;
        p.edge.up_bytes = 4096;
        p.edge.delta_saved = 512;
        rec.push_population(p.clone());
        p.sample.attempts = 10;
        rec.push_population(p);
        let run = RunSummary {
            label: "p".into(),
            final_wer: 20.0,
            final_loss: 1.0,
            param_memory_bytes: 100,
            memory_ratio: 0.5,
            comm_bytes_per_round: 10.0,
            rounds_per_min: 1.0,
            rounds: 2,
        };
        let cell = cell_summary(0, &cfg, "ff", &rec, &run);
        let text = cell.to_string();
        assert!(text.contains("\"population_mode\":true"));
        assert!(text.contains("\"registered\":1000000"));
        assert!(text.contains("\"edges\":4"));
        assert!(text.contains("\"sample_attempts\":22"));
        assert!(text.contains("\"churn_rejections\":4"));
        assert!(text.contains("\"wave_rejections\":6"));
        assert!(text.contains("\"mean_active_estimate\":250000"));
        assert!(text.contains("\"class_sampled\":[8,4,2,2]"));
        assert!(text.contains("\"class_completed\":[8,2,0,0]"));
        // a class nobody completed reads 0; rates stay finite per class
        assert!(text.contains("\"class_completion_rate\":[1,0.5,0,0]"));
        assert!(text.contains("\"edge_frames\":8"));
        assert!(text.contains("\"edge_up_bytes\":8192"));
        assert!(text.contains("\"edge_delta_saved\":1024"));
        // non-population cells carry the flag but no population object —
        // the CI scale gate greps the keys on scale cells only
        let plain = sample_cell().to_string();
        assert!(plain.contains("\"population_mode\":false"));
        assert!(!plain.contains("\"sample_attempts\""));
        // round-trip stability holds with the new fields
        let reparsed = json::parse(&text).unwrap();
        assert_eq!(reparsed.to_string(), text);
    }

    #[test]
    fn inf_and_nan_eval_metrics_round_trip_as_null() {
        // regression: a summary whose eval metrics went non-finite (e.g. a
        // diverged cell with +inf loss, or NaN WER after a fully-dropped
        // run) must still emit parseable JSON whose parse∘write is the
        // identity — never a bare `inf`/`NaN` token
        let cfg = ExperimentConfig::default_with("x", Path::new("native:tiny"));
        let mut rec = Recorder::new("x");
        rec.push(RoundRecord {
            round: 0,
            train_loss: f64::INFINITY,
            eval_loss: 0.5,
            eval_wer: f64::NAN,
            down_bytes: 1,
            up_bytes: 1,
            up_bytes_discarded: 0,
            sampled: 1,
            completed: 1,
            dropped: 0,
            late: 0,
            crashed: 0,
            frames_rejected: 0,
            up_bytes_rejected: 0,
            up_bytes_delta_saved: 0,
            up_bytes_sparse_saved: 0,
            sparse_selected: 0,
            sparse_total: 0,
            sparse_residual_sq: 0.0,
            round_seconds: 0.0,
        });
        let run = RunSummary {
            label: "x".into(),
            final_wer: f64::NAN,
            final_loss: f64::INFINITY,
            param_memory_bytes: 0,
            memory_ratio: f64::NEG_INFINITY,
            comm_bytes_per_round: 0.0,
            rounds_per_min: 0.0,
            rounds: 1,
        };
        let cell = cell_summary(0, &cfg, "ab", &rec, &run);
        let sweep = sweep_summary("diverged", 1, vec![cell]);
        let text = sweep.to_string();
        assert!(text.contains("\"final_wer\":null"));
        assert!(text.contains("\"final_train_loss\":null"));
        assert!(text.contains("\"memory_ratio\":null"));
        for tok in ["inf", "Inf", "NaN", "nan"] {
            assert!(!text.contains(tok), "unparseable token {tok:?} in {text}");
        }
        let reparsed = json::parse(&text).unwrap();
        assert_eq!(reparsed.to_string(), text, "parse∘write must be identity");
    }

    #[test]
    fn nan_fields_serialize_as_null_and_stay_stable() {
        let cfg = ExperimentConfig::default_with("x", Path::new("native:tiny"));
        let rec = Recorder::new("x"); // empty: final_wer is NaN
        let run = RunSummary {
            label: "x".into(),
            final_wer: f64::NAN,
            final_loss: f64::NAN,
            param_memory_bytes: 0,
            memory_ratio: 0.0,
            comm_bytes_per_round: 0.0,
            rounds_per_min: 0.0,
            rounds: 0,
        };
        let cell = cell_summary(3, &cfg, "abcd", &rec, &run);
        let text = cell.to_string();
        assert!(text.contains("\"final_wer\":null"));
        let reparsed = json::parse(&text).unwrap();
        assert_eq!(reparsed.to_string(), text);
    }
}
