//! Run statistics: timers, running moments, percentiles, throughput.

use std::time::Instant;

/// Wall-clock timer with a readable report.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
}

/// Streaming mean/variance (Welford) + min/max.
#[derive(Clone, Debug, Default)]
pub struct Running {
    pub n: usize,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Percentile over a sample (nearest-rank on a sorted copy).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Median absolute deviation — robust spread for bench reports.
pub fn median_abs_dev(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let med = percentile(xs, 50.0);
    let devs: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    percentile(&devs, 50.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_moments() {
        let mut r = Running::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            r.push(x);
        }
        assert!((r.mean() - 5.0).abs() < 1e-12);
        assert!((r.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(r.min, 2.0);
        assert_eq!(r.max, 9.0);
    }

    #[test]
    fn empty_running() {
        let r = Running::new();
        assert_eq!(r.mean(), 0.0);
        assert_eq!(r.var(), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.0).abs() <= 1.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let mut xs = vec![10.0; 99];
        xs.push(10_000.0);
        assert_eq!(median_abs_dev(&xs), 0.0);
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
    }
}
