//! Word Error Rate — the paper's accuracy metric.
//!
//! `WER = (substitutions + insertions + deletions) / reference length`,
//! computed by Levenshtein alignment between hypothesis and reference word
//! sequences and aggregated over a corpus (total edits / total words, the
//! standard convention).

/// Levenshtein distance between two symbol sequences (O(n·m) DP with a
/// rolling row).
pub fn edit_distance(a: &[i32], b: &[i32]) -> usize {
    if a.is_empty() {
        return b.len();
    }
    if b.is_empty() {
        return a.len();
    }
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut curr = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        curr[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let cost = usize::from(ca != cb);
            curr[j + 1] = (prev[j] + cost) // substitution / match
                .min(prev[j + 1] + 1)      // deletion
                .min(curr[j] + 1);         // insertion
        }
        std::mem::swap(&mut prev, &mut curr);
    }
    prev[b.len()]
}

/// Corpus-level WER accumulator.
#[derive(Clone, Debug, Default)]
pub struct WerAccumulator {
    pub edits: usize,
    pub words: usize,
    pub utterances: usize,
}

impl WerAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, hyp: &[i32], reference: &[i32]) {
        self.edits += edit_distance(hyp, reference);
        self.words += reference.len();
        self.utterances += 1;
    }

    pub fn merge(&mut self, other: &WerAccumulator) {
        self.edits += other.edits;
        self.words += other.words;
        self.utterances += other.utterances;
    }

    /// WER in percent (the paper's unit).
    pub fn wer(&self) -> f64 {
        if self.words == 0 {
            return 0.0;
        }
        100.0 * self.edits as f64 / self.words as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_is_zero() {
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 2, 3]), 0);
    }

    #[test]
    fn known_distances() {
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 3]), 1); // deletion
        assert_eq!(edit_distance(&[1, 3], &[1, 2, 3]), 1); // insertion
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 9, 3]), 1); // substitution
        assert_eq!(edit_distance(&[], &[1, 2]), 2);
        assert_eq!(edit_distance(&[7], &[]), 1);
        // classic: kitten -> sitting = 3 (as symbol ids)
        let kitten = [10, 8, 19, 19, 4, 13];
        let sitting = [18, 8, 19, 19, 8, 13, 6];
        assert_eq!(edit_distance(&kitten, &sitting), 3);
    }

    #[test]
    fn symmetric() {
        let a = [1, 2, 3, 4, 5];
        let b = [2, 3, 9];
        assert_eq!(edit_distance(&a, &b), edit_distance(&b, &a));
    }

    #[test]
    fn triangle_inequality_spot() {
        let a = [1, 2, 3];
        let b = [1, 3, 3];
        let c = [4, 4, 4];
        assert!(
            edit_distance(&a, &c)
                <= edit_distance(&a, &b) + edit_distance(&b, &c)
        );
    }

    #[test]
    fn accumulator_aggregates() {
        let mut acc = WerAccumulator::new();
        acc.add(&[1, 2, 3], &[1, 2, 3]); // 0 edits / 3 words
        acc.add(&[1, 9], &[1, 2]); // 1 edit / 2 words
        assert_eq!(acc.utterances, 2);
        assert!((acc.wer() - 20.0).abs() < 1e-9); // 1/5 = 20%
    }

    #[test]
    fn merge_matches_sequential() {
        let mut a = WerAccumulator::new();
        a.add(&[1], &[2]);
        let mut b = WerAccumulator::new();
        b.add(&[3, 4], &[3, 4]);
        let mut m = WerAccumulator::new();
        m.merge(&a);
        m.merge(&b);
        assert_eq!(m.edits, 1);
        assert_eq!(m.words, 3);
        assert_eq!(m.utterances, 2);
    }

    #[test]
    fn empty_accumulator_is_zero() {
        assert_eq!(WerAccumulator::new().wer(), 0.0);
    }

    #[test]
    fn wer_can_exceed_100() {
        // more insertions than reference words
        let mut acc = WerAccumulator::new();
        acc.add(&[1, 2, 3, 4, 5], &[9]);
        assert!(acc.wer() > 100.0);
    }
}
