//! Metrics substrate: WER, run statistics, and round-log recording.

pub mod recorder;
pub mod stats;
pub mod wer;
