//! Metrics substrate: WER, run statistics, round-log recording, and the
//! deterministic sweep summaries.

pub mod recorder;
pub mod stats;
pub mod sweep;
pub mod wer;
