//! # omc-fl — Online Model Compression for Federated Learning
//!
//! A three-layer reproduction of *Online Model Compression for Federated
//! Learning with Large Models* (Yang et al., Interspeech 2022):
//!
//! * **L3 (this crate)** — the federated-learning coordinator: server state,
//!   client scheduling, the OMC compressed parameter store + bit-packing
//!   codec, transport accounting, WER evaluation, metrics and the CLI.
//! * **L2** — the conformer-lite training/eval graphs, written in JAX and
//!   AOT-lowered to HLO text under `artifacts/` (`make artifacts`).
//! * **L1** — the Pallas SxEyMz fake-quantization kernel, lowered inside the
//!   L2 graphs.
//!
//! Python never runs on the request path: [`runtime`] loads the HLO
//! artifacts through the PJRT C API (`xla` crate) and every training step is
//! a compiled executable call.
//!
//! Start with [`coordinator::Experiment`] (driving a whole federated run) or
//! the `examples/` directory, which regenerates every table and figure of
//! the paper (see `DESIGN.md` §5 for the experiment index).

pub mod benchkit;
pub mod coordinator;
pub mod data;
pub mod fl;
pub mod metrics;
pub mod model;
pub mod omc;
pub mod runtime;
pub mod testkit;
pub mod util;

pub use omc::format::FloatFormat;
