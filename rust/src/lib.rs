//! # omc-fl — Online Model Compression for Federated Learning
//!
//! A three-layer reproduction of *Online Model Compression for Federated
//! Learning with Large Models* (Yang et al., Interspeech 2022):
//!
//! * **L3 (this crate)** — the federated-learning coordinator: server state,
//!   client scheduling, the OMC compressed parameter store + bit-packing
//!   codec, transport accounting, WER evaluation, metrics and the CLI.
//! * **L2** — the conformer-lite training/eval graphs, written in JAX and
//!   AOT-lowered to HLO text under `artifacts/`
//!   (`python python/compile/aot.py --out-dir artifacts`).
//! * **L1** — the Pallas SxEyMz fake-quantization kernel, lowered inside the
//!   L2 graphs.
//!
//! Python never runs on the request path: [`runtime`] loads the HLO
//! artifacts through the PJRT C API (`xla` crate) and every training step is
//! a compiled executable call.
//!
//! # Crate map
//!
//! * [`omc`] — the compression core: `SxEyMz` formats, the bit-exact
//!   quantizer mirror, per-variable transforms, the block bit-packing
//!   kernels and fused pipelines, the compressed store, and the wire
//!   codec with its lossless cross-round delta stage ([`omc::delta`];
//!   frame layouts and the ack state machine are specified in
//!   `docs/WIRE.md`) and its top-k / rand-k uplink sparsification stage
//!   with per-client error feedback ([`omc::sparse`]; record layout,
//!   index bitpacking, and the error-feedback contract are specified in
//!   `docs/COMPRESSION.md`). Fully documented (`#![warn(missing_docs)]`).
//! * [`fl`] — the federated substrate: [`fl::server`] (reference FedAvg +
//!   the streaming [`fl::server::StreamingAggregator`]), [`fl::client`]
//!   (one simulated client round, zero-alloc codec contract),
//!   [`fl::cohort`] (dropout / straggler / weighted-FedAvg failure
//!   scenarios), [`fl::sampler`], [`fl::round`] — the streaming, sharded
//!   synchronous round engine — and [`fl::async_round`] — the buffered
//!   staleness-aware asynchronous engine (virtual-time planned, commits
//!   byte-identical for any worker count; `docs/ASYNC.md`). [`fl::chaos`]
//!   injects deterministic wire faults (corruption, replays, crashes,
//!   commit failures) against the checksummed v2 frame layout of
//!   [`omc::codec`], with retry/backoff and a quarantine ladder —
//!   `docs/ROBUSTNESS.md` documents the integrity and fault contracts.
//!   [`fl::population`] scales the simulator to 10^6–10^7 *registered*
//!   clients in O(active) memory: lazy `(seed, cid)`-derived profiles,
//!   churn and diurnal availability, a device-class ladder, a streaming
//!   rejection sampler, and two-tier edge→root aggregation over the same
//!   wire stack — `docs/SCALE.md` documents the topology and contracts.
//!   [`fl::serve`] executes the async plan on real worker threads for
//!   wall-clock measurement: lock-free epoch-published snapshots
//!   ([`omc::store::SnapshotPublisher`]), arena-pooled frames
//!   ([`util::arena`]), and a bounded uplink queue with backpressure —
//!   committed bytes stay bit-identical to the planned timeline
//!   (`docs/SERVING.md` documents the threading model and contracts).
//! * [`coordinator`] — experiment configs (TOML or builders), the
//!   [`coordinator::Experiment`] driver, presets for the paper's tables
//!   (including the [`coordinator::presets`] sweep grids), the
//!   [`coordinator::sweep`] grid engine with byte-deterministic
//!   summaries, and checkpoint I/O.
//! * [`runtime`] — the PJRT engine behind the `pjrt` feature, plus the
//!   pure-Rust executable [`runtime::native`] backend (`native:tiny`)
//!   available in every build; default builds get an API-identical stub
//!   for the artifact-backed paths so the pure-Rust stack builds and
//!   tests without the XLA toolchain.
//! * [`data`] / [`metrics`] — synthetic ASR task + client partitioning,
//!   WER / round-log recording, and the deterministic sweep summaries
//!   ([`metrics::sweep`]).
//! * [`benchkit`] / [`testkit`] / [`util`] — the bench harness
//!   (`OMC_BENCH_JSON` emits `BENCH_*.json`), property-test helpers, and
//!   the dependency-free substrate (RNG, thread pool, TOML/JSON, CLI,
//!   and the [`util::simd`] runtime kernel dispatch —
//!   `docs/PERFORMANCE.md` documents the determinism contract).
//!
//! Start with [`coordinator::Experiment`] (driving a whole federated run)
//! or the `examples/` directory, which regenerates every table and figure
//! of the paper — `README.md` has the quickstart and
//! `docs/REPRODUCING.md` maps each example to its table/figure.

pub mod benchkit;
pub mod coordinator;
pub mod data;
pub mod fl;
pub mod metrics;
pub mod model;
pub mod omc;
pub mod runtime;
pub mod testkit;
pub mod util;

pub use omc::format::FloatFormat;
