//! Federated client partitioning (Sec. 3.1).
//!
//! * **IID** — every client samples utterances from every speaker (the
//!   paper's random partition of LibriSpeech).
//! * **By-speaker (non-IID)** — each client owns a disjoint speaker subset
//!   (the paper's partition-by-speaker), so client data distributions
//!   differ through the speaker channel vectors.

use crate::util::rng::{hash_seed, Xoshiro256pp};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Partition {
    Iid,
    BySpeaker,
}

impl Partition {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "iid" => Ok(Partition::Iid),
            "by_speaker" | "non_iid" => Ok(Partition::BySpeaker),
            other => anyhow::bail!("unknown partition {other:?} (iid | by_speaker)"),
        }
    }
}

impl std::fmt::Display for Partition {
    /// The canonical config spelling — `parse(x.to_string())` round-trips,
    /// and the sweep summaries/fingerprints use this form.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Partition::Iid => "iid",
            Partition::BySpeaker => "by_speaker",
        })
    }
}

/// The speaker sets assigned to each client.
#[derive(Clone, Debug)]
pub struct ClientAssignment {
    pub speakers_per_client: Vec<Vec<usize>>,
}

impl ClientAssignment {
    pub fn build(
        partition: Partition,
        num_clients: usize,
        num_speakers: usize,
        seed: u64,
    ) -> Self {
        assert!(num_clients > 0 && num_speakers > 0);
        let speakers_per_client = match partition {
            Partition::Iid => {
                // every client sees every speaker
                (0..num_clients)
                    .map(|_| (0..num_speakers).collect())
                    .collect()
            }
            Partition::BySpeaker => {
                // disjoint speaker shards, sizes differing by at most 1
                let mut ids: Vec<usize> = (0..num_speakers).collect();
                let mut rng =
                    Xoshiro256pp::new(hash_seed(&[seed, 0x5411_AD]));
                rng.shuffle(&mut ids);
                let mut shards: Vec<Vec<usize>> =
                    (0..num_clients).map(|_| Vec::new()).collect();
                for (i, spk) in ids.into_iter().enumerate() {
                    shards[i % num_clients].push(spk);
                }
                // a client must own at least one speaker: when there are
                // fewer speakers than clients, wrap around (the overlap is
                // unavoidable and still far from IID)
                for c in 0..num_clients {
                    if shards[c].is_empty() {
                        shards[c].push(c % num_speakers);
                    }
                }
                shards
            }
        };
        Self {
            speakers_per_client,
        }
    }

    pub fn num_clients(&self) -> usize {
        self.speakers_per_client.len()
    }

    pub fn speakers(&self, client: usize) -> &[usize] {
        &self.speakers_per_client[client]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iid_gives_everyone_everything() {
        let a = ClientAssignment::build(Partition::Iid, 8, 32, 1);
        for c in 0..8 {
            assert_eq!(a.speakers(c).len(), 32);
        }
    }

    #[test]
    fn by_speaker_is_disjoint_and_complete() {
        let a = ClientAssignment::build(Partition::BySpeaker, 8, 32, 1);
        let mut seen = vec![0usize; 32];
        for c in 0..8 {
            assert_eq!(a.speakers(c).len(), 4);
            for &s in a.speakers(c) {
                seen[s] += 1;
            }
        }
        assert!(seen.iter().all(|&n| n == 1), "{seen:?}");
    }

    #[test]
    fn by_speaker_uneven_split() {
        let a = ClientAssignment::build(Partition::BySpeaker, 3, 10, 2);
        let sizes: Vec<usize> =
            (0..3).map(|c| a.speakers(c).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().all(|&s| s == 3 || s == 4));
    }

    #[test]
    fn more_clients_than_speakers_still_nonempty() {
        let a = ClientAssignment::build(Partition::BySpeaker, 10, 4, 3);
        for c in 0..10 {
            assert!(!a.speakers(c).is_empty());
        }
    }

    #[test]
    fn deterministic() {
        let a = ClientAssignment::build(Partition::BySpeaker, 8, 32, 42);
        let b = ClientAssignment::build(Partition::BySpeaker, 8, 32, 42);
        let c = ClientAssignment::build(Partition::BySpeaker, 8, 32, 43);
        assert_eq!(a.speakers_per_client, b.speakers_per_client);
        assert_ne!(a.speakers_per_client, c.speakers_per_client);
    }

    #[test]
    fn parse_names() {
        assert_eq!(Partition::parse("iid").unwrap(), Partition::Iid);
        assert_eq!(
            Partition::parse("by_speaker").unwrap(),
            Partition::BySpeaker
        );
        assert_eq!(Partition::parse("non_iid").unwrap(), Partition::BySpeaker);
        assert!(Partition::parse("other").is_err());
    }
}
