//! Federated client partitioning (Sec. 3.1).
//!
//! * **IID** — every client samples utterances from every speaker (the
//!   paper's random partition of LibriSpeech).
//! * **By-speaker (non-IID)** — each client owns a disjoint speaker subset
//!   (the paper's partition-by-speaker), so client data distributions
//!   differ through the speaker channel vectors.

use crate::util::rng::{hash_seed, Xoshiro256pp};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Partition {
    Iid,
    BySpeaker,
}

impl Partition {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "iid" => Ok(Partition::Iid),
            "by_speaker" | "non_iid" => Ok(Partition::BySpeaker),
            other => anyhow::bail!("unknown partition {other:?} (iid | by_speaker)"),
        }
    }
}

impl std::fmt::Display for Partition {
    /// The canonical config spelling — `parse(x.to_string())` round-trips,
    /// and the sweep summaries/fingerprints use this form.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Partition::Iid => "iid",
            Partition::BySpeaker => "by_speaker",
        })
    }
}

/// Lazy backing store: O(num_speakers) state from which any client's
/// shard is recomputed on demand — the population-scale path where
/// materializing `registered` shard vectors is not an option.
#[derive(Clone, Debug)]
enum Repr {
    /// `speakers_per_client` is fully materialized (the classic path)
    Dense,
    /// every client owns every speaker; one shared shard vector
    LazyIid { num_clients: usize, all: Vec<usize> },
    /// the shuffled speaker order — client `c` owns `order[c]`,
    /// `order[c + num_clients]`, … (the exact strided assignment the
    /// dense builder produces), falling back to `c % num_speakers` when
    /// the stride gives it nothing
    LazyBySpeaker {
        num_clients: usize,
        order: Vec<usize>,
    },
}

/// The speaker sets assigned to each client.
///
/// Dense ([`build`](Self::build)) and lazy ([`lazy`](Self::lazy)) modes
/// are bit-identical for every population both can represent — the
/// property tests in this module pin that. Engines read shards through
/// [`speakers_of`](Self::speakers_of) / [`num_examples`](Self::num_examples),
/// which work in both modes; [`speakers`](Self::speakers) stays for the
/// dense-only callers.
#[derive(Clone, Debug)]
pub struct ClientAssignment {
    pub speakers_per_client: Vec<Vec<usize>>,
    repr: Repr,
}

impl ClientAssignment {
    pub fn build(
        partition: Partition,
        num_clients: usize,
        num_speakers: usize,
        seed: u64,
    ) -> Self {
        assert!(num_clients > 0 && num_speakers > 0);
        let speakers_per_client = match partition {
            Partition::Iid => {
                // every client sees every speaker
                (0..num_clients)
                    .map(|_| (0..num_speakers).collect())
                    .collect()
            }
            Partition::BySpeaker => {
                // disjoint speaker shards, sizes differing by at most 1
                let mut shards: Vec<Vec<usize>> =
                    (0..num_clients).map(|_| Vec::new()).collect();
                for (i, spk) in
                    shuffled_order(num_speakers, seed).into_iter().enumerate()
                {
                    shards[i % num_clients].push(spk);
                }
                // a client must own at least one speaker: when there are
                // fewer speakers than clients, wrap around (the overlap is
                // unavoidable and still far from IID)
                for c in 0..num_clients {
                    if shards[c].is_empty() {
                        shards[c].push(c % num_speakers);
                    }
                }
                shards
            }
        };
        Self {
            speakers_per_client,
            repr: Repr::Dense,
        }
    }

    /// O(num_speakers)-memory assignment over `num_clients` clients —
    /// the same shards [`build`](Self::build) would produce, derived on
    /// demand instead of stored. `num_clients` can be 10^7; only the
    /// shuffled speaker order (tiny) is kept.
    pub fn lazy(
        partition: Partition,
        num_clients: usize,
        num_speakers: usize,
        seed: u64,
    ) -> Self {
        assert!(num_clients > 0 && num_speakers > 0);
        let repr = match partition {
            Partition::Iid => Repr::LazyIid {
                num_clients,
                all: (0..num_speakers).collect(),
            },
            Partition::BySpeaker => Repr::LazyBySpeaker {
                num_clients,
                order: shuffled_order(num_speakers, seed),
            },
        };
        Self {
            speakers_per_client: Vec::new(),
            repr,
        }
    }

    pub fn num_clients(&self) -> usize {
        match &self.repr {
            Repr::Dense => self.speakers_per_client.len(),
            Repr::LazyIid { num_clients, .. }
            | Repr::LazyBySpeaker { num_clients, .. } => *num_clients,
        }
    }

    /// Dense-only borrow of a client's shard (panics in lazy mode —
    /// engines use [`speakers_of`](Self::speakers_of)).
    pub fn speakers(&self, client: usize) -> &[usize] {
        match &self.repr {
            Repr::Dense => &self.speakers_per_client[client],
            Repr::LazyIid { all, .. } => all,
            Repr::LazyBySpeaker { .. } => panic!(
                "speakers() cannot borrow from a lazy by-speaker \
                 assignment; use speakers_of()"
            ),
        }
    }

    /// A client's shard in either mode. Dense and lazy-IID borrow;
    /// lazy-by-speaker recomputes the strided pick (O(own shard), which
    /// is O(num_speakers / num_clients + 1) — a handful of indices).
    pub fn speakers_of(&self, client: usize) -> std::borrow::Cow<'_, [usize]> {
        match &self.repr {
            Repr::Dense => {
                std::borrow::Cow::Borrowed(&self.speakers_per_client[client])
            }
            Repr::LazyIid { all, .. } => std::borrow::Cow::Borrowed(all),
            Repr::LazyBySpeaker { num_clients, order } => {
                let mut own: Vec<usize> = order
                    .iter()
                    .copied()
                    .skip(client)
                    .step_by(*num_clients)
                    .collect();
                if own.is_empty() {
                    own.push(client % order.len());
                }
                std::borrow::Cow::Owned(own)
            }
        }
    }

    /// Number of examples (speakers) client `client` owns — O(1) in every
    /// mode, the weighted-FedAvg input at population scale.
    pub fn num_examples(&self, client: usize) -> usize {
        match &self.repr {
            Repr::Dense => self.speakers_per_client[client].len(),
            Repr::LazyIid { all, .. } => all.len(),
            Repr::LazyBySpeaker { num_clients, order } => {
                let num_speakers = order.len();
                if client < num_speakers {
                    // count of i in [0, num_speakers) with
                    // i % num_clients == client
                    (num_speakers - 1 - client) / num_clients + 1
                } else {
                    // stride assigns nothing; the wraparound fallback
                    // always owns exactly one speaker
                    1
                }
            }
        }
    }
}

/// The by-speaker shuffle both modes share — keyed only by `(seed,
/// 0x5411_AD)`, so dense and lazy assignments of the same parameters see
/// the same speaker order.
fn shuffled_order(num_speakers: usize, seed: u64) -> Vec<usize> {
    let mut ids: Vec<usize> = (0..num_speakers).collect();
    let mut rng = Xoshiro256pp::new(hash_seed(&[seed, 0x5411_AD]));
    rng.shuffle(&mut ids);
    ids
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iid_gives_everyone_everything() {
        let a = ClientAssignment::build(Partition::Iid, 8, 32, 1);
        for c in 0..8 {
            assert_eq!(a.speakers(c).len(), 32);
        }
    }

    #[test]
    fn by_speaker_is_disjoint_and_complete() {
        let a = ClientAssignment::build(Partition::BySpeaker, 8, 32, 1);
        let mut seen = vec![0usize; 32];
        for c in 0..8 {
            assert_eq!(a.speakers(c).len(), 4);
            for &s in a.speakers(c) {
                seen[s] += 1;
            }
        }
        assert!(seen.iter().all(|&n| n == 1), "{seen:?}");
    }

    #[test]
    fn by_speaker_uneven_split() {
        let a = ClientAssignment::build(Partition::BySpeaker, 3, 10, 2);
        let sizes: Vec<usize> =
            (0..3).map(|c| a.speakers(c).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().all(|&s| s == 3 || s == 4));
    }

    #[test]
    fn more_clients_than_speakers_still_nonempty() {
        let a = ClientAssignment::build(Partition::BySpeaker, 10, 4, 3);
        for c in 0..10 {
            assert!(!a.speakers(c).is_empty());
        }
    }

    #[test]
    fn deterministic() {
        let a = ClientAssignment::build(Partition::BySpeaker, 8, 32, 42);
        let b = ClientAssignment::build(Partition::BySpeaker, 8, 32, 42);
        let c = ClientAssignment::build(Partition::BySpeaker, 8, 32, 43);
        assert_eq!(a.speakers_per_client, b.speakers_per_client);
        assert_ne!(a.speakers_per_client, c.speakers_per_client);
    }

    /// Property: for every population the dense path can represent, the
    /// lazy derivation returns bit-identical shards — the contract that
    /// lets population-mode cells claim the same semantics as the
    /// materialized sweep cells (`docs/SCALE.md`).
    #[test]
    fn lazy_matches_dense_bit_identically() {
        for partition in [Partition::Iid, Partition::BySpeaker] {
            for &(nc, ns) in
                &[(1, 1), (3, 10), (8, 32), (10, 4), (64, 64), (97, 13)]
            {
                for seed in [0u64, 1, 42, 0xDEAD] {
                    let dense =
                        ClientAssignment::build(partition, nc, ns, seed);
                    let lazy =
                        ClientAssignment::lazy(partition, nc, ns, seed);
                    assert_eq!(lazy.num_clients(), dense.num_clients());
                    for c in 0..nc {
                        assert_eq!(
                            lazy.speakers_of(c).as_ref(),
                            dense.speakers(c),
                            "{partition:?} nc={nc} ns={ns} seed={seed} c={c}"
                        );
                        assert_eq!(
                            lazy.num_examples(c),
                            dense.speakers(c).len(),
                            "{partition:?} nc={nc} ns={ns} seed={seed} c={c}"
                        );
                        assert_eq!(
                            dense.speakers_of(c).as_ref(),
                            dense.speakers(c)
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn lazy_scales_to_millions_without_materializing() {
        // 10^6 clients over 64 speakers: O(speakers) state, O(1) queries
        let a = ClientAssignment::lazy(Partition::BySpeaker, 1_000_000, 64, 7);
        assert_eq!(a.num_clients(), 1_000_000);
        assert!(a.speakers_per_client.is_empty(), "nothing materialized");
        // the first 64 clients own exactly the shuffled speakers...
        let mut owned: Vec<usize> =
            (0..64).flat_map(|c| a.speakers_of(c).into_owned()).collect();
        owned.sort_unstable();
        assert_eq!(owned, (0..64).collect::<Vec<_>>());
        // ...and everyone else wraps around to a single speaker
        for c in [64usize, 1000, 999_999] {
            assert_eq!(a.speakers_of(c).as_ref(), &[c % 64]);
            assert_eq!(a.num_examples(c), 1);
        }
    }

    #[test]
    fn parse_names() {
        assert_eq!(Partition::parse("iid").unwrap(), Partition::Iid);
        assert_eq!(
            Partition::parse("by_speaker").unwrap(),
            Partition::BySpeaker
        );
        assert_eq!(Partition::parse("non_iid").unwrap(), Partition::BySpeaker);
        assert!(Partition::parse("other").is_err());
    }
}
