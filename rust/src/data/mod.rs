//! Data substrate: synthetic ASR-like workload + federated partitioning.
//!
//! Substitutes LibriSpeech / the Multi-Domain corpus (unavailable offline)
//! with a task that exercises identical code paths — see DESIGN.md §2 for
//! the substitution argument.

pub mod partition;
pub mod synth;
