//! Synthetic ASR-like task (the LibriSpeech / Multi-Domain stand-in).
//!
//! Generative model per utterance:
//!
//! 1. a token sequence `y[t]` is drawn from a domain-conditioned Markov-ish
//!    process (tokens cluster into "words" of a few frames — giving the
//!    edit-distance WER something word-like to measure);
//! 2. features are emitted as `x[t] = E_dom[y[t]] + c_speaker + σ·noise`,
//!    where `E_dom` is the domain's fixed random "acoustic" embedding,
//!    `c_speaker` a per-speaker channel vector (the non-IID axis), and σ the
//!    acoustic noise level.
//!
//! A model must invert the noisy emission to transcribe — so WER falls with
//! training, degrades with quantization error, and shifts across domains,
//! which is all the paper's evaluation needs from the data (DESIGN.md §2).

use crate::util::rng::{hash_seed, Xoshiro256pp};

/// Static description of the task; shared by train and eval generators.
#[derive(Clone, Debug)]
pub struct TaskConfig {
    pub vocab: usize,
    pub feature_dim: usize,
    pub seq_len: usize,
    /// frames per "word" (tokens repeat within a word slot)
    pub word_len: usize,
    /// acoustic noise σ
    pub noise: f32,
    /// per-speaker channel strength
    pub speaker_shift: f32,
    pub num_speakers: usize,
    pub seed: u64,
}

impl TaskConfig {
    pub fn from_model(vocab: usize, feature_dim: usize, seq_len: usize, seed: u64) -> Self {
        Self {
            vocab,
            feature_dim,
            seq_len,
            word_len: 4,
            noise: 0.3,
            speaker_shift: 0.5,
            num_speakers: 64,
            seed,
        }
    }
}

/// One emission domain (Sec. 3.1's MF / non-MF analog): its own embedding
/// table, token prior and noise profile.
pub struct Domain {
    pub id: u64,
    embed: Vec<f32>,   // [vocab, feature_dim]
    prior: Vec<f64>,   // token distribution (non-uniform, domain-specific)
    speakers: Vec<Vec<f32>>,
    cfg: TaskConfig,
}

impl Domain {
    pub fn new(cfg: &TaskConfig, domain_id: u64) -> Self {
        let mut rng = Xoshiro256pp::new(hash_seed(&[cfg.seed, 0xD0_4A15, domain_id]));
        let mut embed = vec![0.0f32; cfg.vocab * cfg.feature_dim];
        rng.fill_normal(&mut embed, 1.0);
        // Zipf-ish token prior, permuted per domain so domains differ in
        // which tokens dominate (the "domain shift")
        let mut order: Vec<usize> = (0..cfg.vocab).collect();
        rng.shuffle(&mut order);
        let mut prior = vec![0.0f64; cfg.vocab];
        for (rank, &tok) in order.iter().enumerate() {
            prior[tok] = 1.0 / (rank as f64 + 2.0);
        }
        let total: f64 = prior.iter().sum();
        for p in prior.iter_mut() {
            *p /= total;
        }
        let speakers = (0..cfg.num_speakers)
            .map(|s| {
                let mut rs = rng.derive(&[0x5bea_0000, s as u64]);
                let mut c = vec![0.0f32; cfg.feature_dim];
                rs.fill_normal(&mut c, cfg.speaker_shift);
                c
            })
            .collect();
        Self {
            id: domain_id,
            embed,
            prior,
            speakers,
            cfg: cfg.clone(),
        }
    }

    fn cfg(&self) -> &TaskConfig {
        &self.cfg
    }

    /// Generate one utterance for `speaker`; returns (features, tokens).
    pub fn utterance(
        &self,
        speaker: usize,
        rng: &mut Xoshiro256pp,
    ) -> (Vec<f32>, Vec<i32>) {
        let cfg = self.cfg();
        let t = cfg.seq_len;
        let f = cfg.feature_dim;
        let mut tokens = Vec::with_capacity(t);
        // word-structured token sequence: each word slot repeats one token
        while tokens.len() < t {
            let tok = rng.choice_weighted(&self.prior) as i32;
            for _ in 0..cfg.word_len {
                if tokens.len() < t {
                    tokens.push(tok);
                }
            }
        }
        let chan = &self.speakers[speaker % self.speakers.len()];
        let mut x = vec![0.0f32; t * f];
        for (ti, &tok) in tokens.iter().enumerate() {
            let e = &self.embed[tok as usize * f..(tok as usize + 1) * f];
            for fi in 0..f {
                x[ti * f + fi] = e[fi]
                    + chan[fi]
                    + (rng.next_normal() as f32) * cfg.noise;
            }
        }
        (x, tokens)
    }

    /// Generate a batch `[bs, T, F]` + labels `[bs, T]` for one client's
    /// speaker set (flat row-major, matching the HLO operand layout).
    pub fn batch(
        &self,
        speakers: &[usize],
        bs: usize,
        rng: &mut Xoshiro256pp,
    ) -> Batch {
        let cfg = self.cfg();
        let mut x = Vec::with_capacity(bs * cfg.seq_len * cfg.feature_dim);
        let mut y = Vec::with_capacity(bs * cfg.seq_len);
        for _ in 0..bs {
            let spk = speakers[rng.next_below(speakers.len() as u64) as usize];
            let (xu, yu) = self.utterance(spk, rng);
            x.extend_from_slice(&xu);
            y.extend_from_slice(&yu);
        }
        Batch {
            x,
            y,
            batch: bs,
            seq_len: cfg.seq_len,
            feature_dim: cfg.feature_dim,
            word_len: cfg.word_len,
        }
    }
}

/// A generated batch in HLO operand layout.
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub batch: usize,
    pub seq_len: usize,
    pub feature_dim: usize,
    pub word_len: usize,
}

impl Batch {
    /// Reference word sequences (tokens collapsed per word slot) for WER.
    pub fn reference_words(&self) -> Vec<Vec<i32>> {
        (0..self.batch)
            .map(|b| collapse_words(&self.y[b * self.seq_len..(b + 1) * self.seq_len], self.word_len))
            .collect()
    }
}

/// Collapse a framewise token sequence into word-level symbols by majority
/// vote within each word slot (used for both references and hypotheses).
pub fn collapse_words(frames: &[i32], word_len: usize) -> Vec<i32> {
    frames
        .chunks(word_len)
        .map(|chunk| {
            // majority vote; ties resolved toward the smallest token id
            let mut counts = std::collections::BTreeMap::new();
            for &t in chunk {
                *counts.entry(t).or_insert(0usize) += 1;
            }
            counts
                .into_iter()
                .max_by_key(|&(tok, c)| (c, std::cmp::Reverse(tok)))
                .map(|(tok, _)| tok)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> TaskConfig {
        TaskConfig::from_model(32, 16, 16, 7)
    }

    #[test]
    fn deterministic_given_seed() {
        let d1 = Domain::new(&cfg(), 0);
        let d2 = Domain::new(&cfg(), 0);
        let mut r1 = Xoshiro256pp::new(1);
        let mut r2 = Xoshiro256pp::new(1);
        let (x1, y1) = d1.utterance(3, &mut r1);
        let (x2, y2) = d2.utterance(3, &mut r2);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn domains_differ() {
        let a = Domain::new(&cfg(), 0);
        let b = Domain::new(&cfg(), 1);
        assert_ne!(a.embed, b.embed);
        assert_ne!(a.prior, b.prior);
    }

    #[test]
    fn utterance_shapes_and_ranges() {
        let d = Domain::new(&cfg(), 0);
        let mut r = Xoshiro256pp::new(2);
        let (x, y) = d.utterance(0, &mut r);
        assert_eq!(x.len(), 16 * 16);
        assert_eq!(y.len(), 16);
        assert!(y.iter().all(|&t| (0..32).contains(&t)));
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn word_structure_present() {
        let d = Domain::new(&cfg(), 0);
        let mut r = Xoshiro256pp::new(3);
        let (_, y) = d.utterance(0, &mut r);
        // with word_len 4 the first 4 frames share a token
        assert!(y[0] == y[1] && y[1] == y[2] && y[2] == y[3]);
    }

    #[test]
    fn batch_layout() {
        let d = Domain::new(&cfg(), 0);
        let mut r = Xoshiro256pp::new(4);
        let b = d.batch(&[0, 1, 2], 5, &mut r);
        assert_eq!(b.x.len(), 5 * 16 * 16);
        assert_eq!(b.y.len(), 5 * 16);
        assert_eq!(b.reference_words().len(), 5);
        assert_eq!(b.reference_words()[0].len(), 4); // 16 frames / 4
    }

    #[test]
    fn speakers_shift_features() {
        let d = Domain::new(&cfg(), 0);
        // same rng stream, different speakers -> different features
        let mut r1 = Xoshiro256pp::new(5);
        let mut r2 = Xoshiro256pp::new(5);
        let (x1, y1) = d.utterance(0, &mut r1);
        let (x2, y2) = d.utterance(1, &mut r2);
        assert_eq!(y1, y2); // token draw independent of speaker
        assert_ne!(x1, x2);
    }

    #[test]
    fn collapse_words_majority() {
        assert_eq!(collapse_words(&[1, 1, 2, 1, 3, 3, 3, 3], 4), vec![1, 3]);
        assert_eq!(collapse_words(&[5, 5, 5], 4), vec![5]); // ragged tail
    }

    #[test]
    fn prior_is_normalized_and_nonuniform() {
        let d = Domain::new(&cfg(), 0);
        let total: f64 = d.prior.iter().sum();
        assert!((total - 1.0).abs() < 1e-12);
        let max = d.prior.iter().cloned().fold(0.0, f64::max);
        let min = d.prior.iter().cloned().fold(1.0, f64::min);
        assert!(max / min > 3.0, "prior should be skewed");
    }
}
