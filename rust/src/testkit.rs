//! Mini property-testing harness (no `proptest` offline).
//!
//! [`Gen`] produces seeded random values with the distributions our
//! invariants care about (normal-ish magnitudes, wide exponent ranges,
//! special values), and [`check`] runs a property over many cases printing
//! the failing seed so a failure reproduces with `Gen::new(seed)`.
//!
//! The second half is the **wire-frame generator and corruption driver**
//! shared by the codec unit tests, the `wire_delta` property suite, and
//! the chaos regression tests: canonical mixed-shape models
//! ([`sample_wire_model`]), per-version frame builders
//! ([`encode_frame_v2`], [`encode_frame_v3`]), near-identical next-round
//! models for delta coverage ([`perturbed_model`]), and the corruption
//! primitives ([`flip_bit`], [`corrupt_byte`], [`truncate_at`],
//! [`random_bytes`]) every fuzz-style test drives frames through.

use crate::omc::codec::{self, DeltaScratch, WireWriter};
use crate::omc::delta::DeltaBase;
use crate::omc::format::FloatFormat;
use crate::omc::store::{CompressedModel, StoredVar};
use crate::util::rng::Xoshiro256pp;

/// Seeded random input generator for property tests.
pub struct Gen {
    rng: Xoshiro256pp,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Xoshiro256pp::new(seed ^ 0x7E57_7E57_7E57_7E57),
            seed,
        }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.rng.next_below(n as u64) as usize
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.next_f64()
    }

    /// Normal(0, scale) — matches the magnitude profile of NN weights.
    pub fn f32_normalish(&mut self, scale: f32) -> f32 {
        (self.rng.next_normal() as f32) * scale
    }

    /// Wide-exponent f32: random sign/exponent/mantissa with exponent
    /// spread over most of the f32 range plus occasional special values —
    /// the adversarial distribution for quantizer properties.
    pub fn f32_wide(&mut self) -> f32 {
        match self.rng.next_below(20) {
            0 => 0.0,
            1 => -0.0,
            2 => f32::MIN_POSITIVE,
            3 => -f32::MIN_POSITIVE,
            4 => f32::from_bits(1), // smallest subnormal
            _ => {
                let exp = self.rng.next_below(240) as u32 + 8; // biased 8..248
                let frac = self.rng.next_u32() & 0x7F_FFFF;
                let sign = (self.rng.next_u32() & 1) << 31;
                f32::from_bits(sign | (exp << 23) | frac)
            }
        }
    }

    /// Vector of normal-ish values.
    pub fn vec_normal(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_normalish(scale)).collect()
    }

    /// Edge-case-heavy raw f32 vector for kernel bit-exactness tests:
    /// signed zeros, ±inf, an f32 subnormal, and normals across tiny /
    /// huge / ordinary scales — every eighth slot cycles the specials so
    /// any SIMD lane position sees each of them.
    pub fn vec_edge_heavy(&mut self, n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| match i % 8 {
                0 => 0.0,
                1 => -0.0,
                2 => f32::INFINITY,
                3 => f32::NEG_INFINITY,
                4 => 1e-40, // f32 subnormal
                5 => self.f32_normalish(1e-7),
                6 => self.f32_normalish(1e5),
                _ => {
                    let scale = [1e-3, 0.05, 1.0][self.usize_below(3)];
                    self.f32_normalish(scale)
                }
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// wire-frame generator + corruption driver
// ---------------------------------------------------------------------------

/// The canonical mixed-shape model the codec tests exercise: PVT-packed,
/// raw, packed-without-PVT, and empty variables in one frame.
pub fn sample_wire_model(g: &mut Gen) -> CompressedModel {
    let fmt: FloatFormat = "S1E3M7".parse().expect("valid format");
    CompressedModel::new(vec![
        StoredVar::compress(&g.vec_normal(1000, 0.05), fmt, true),
        StoredVar::raw(g.vec_normal(64, 1.0)),
        StoredVar::compress(&g.vec_normal(333, 0.2), fmt, false),
        StoredVar::raw(vec![]),
    ])
}

/// A next-version model derived from `base`: identical shapes and
/// formats, with up to `flips` payload bytes perturbed per packed
/// variable — the converging-training regime the delta stage targets
/// (every code bit pattern is decodable, so direct payload perturbation
/// stays a valid model).
pub fn perturbed_model(
    g: &mut Gen,
    base: &CompressedModel,
    flips: usize,
) -> CompressedModel {
    let mut m = base.clone();
    for var in &mut m.vars {
        if let StoredVar::Packed { bytes, .. } = var {
            if bytes.is_empty() {
                continue;
            }
            for _ in 0..flips {
                let i = g.usize_below(bytes.len());
                bytes[i] ^= (g.u64() & 0xFF) as u8;
            }
        }
    }
    m
}

/// Encode a model as a checksummed v2 frame carrying `nonce`.
pub fn encode_frame_v2(model: &CompressedModel, nonce: u64) -> Vec<u8> {
    let mut w = WireWriter::with_integrity(0, nonce);
    for v in &model.vars {
        w.var(v);
    }
    w.finish()
}

/// Encode a model as a v3 delta frame against `base`, returning the
/// frame and the bytes the delta stage saved vs verbatim records.
pub fn encode_frame_v3(
    model: &CompressedModel,
    nonce: u64,
    base: &DeltaBase<'_>,
) -> (Vec<u8>, usize) {
    let mut w = WireWriter::with_delta(0, nonce, base.version);
    let mut scratch = DeltaScratch::default();
    for (i, v) in model.vars.iter().enumerate() {
        w.var_delta(v, base.var(i), &mut scratch);
    }
    let saved = w.delta_saved();
    (w.finish(), saved)
}

/// `len` independently random bytes — the adversarial byte-soup input.
pub fn random_bytes(g: &mut Gen, len: usize) -> Vec<u8> {
    (0..len).map(|_| (g.u64() & 0xFF) as u8).collect()
}

/// Flip one bit, indexed over the whole buffer (`bit / 8` is the byte,
/// `bit % 8` the bit within it).
pub fn flip_bit(buf: &mut [u8], bit: usize) {
    buf[bit / 8] ^= 1 << (bit % 8);
}

/// XOR byte `at` with `xor` (a no-op corruption when `xor == 0`).
pub fn corrupt_byte(buf: &mut [u8], at: usize, xor: u8) {
    buf[at] ^= xor;
}

/// The prefix of `bytes` of length `len` — the truncation driver
/// (named so corruption loops read uniformly with [`flip_bit`]).
pub fn truncate_at(bytes: &[u8], len: usize) -> &[u8] {
    &bytes[..len]
}

/// Decode a frame via [`codec::for_each_var_based`] and collect each
/// variable's decompressed values — the equality oracle the round-trip
/// and delta-vs-verbatim properties compare on.
pub fn decode_all_based(
    bytes: &[u8],
    base: Option<&DeltaBase<'_>>,
) -> Result<Vec<Vec<f32>>, codec::DecodeError> {
    let mut out = Vec::new();
    codec::for_each_var_based(bytes, base, |_, view| {
        let mut v = Vec::new();
        view.decompress_into(&mut v);
        out.push(v);
        Ok(())
    })?;
    Ok(out)
}

/// Run `prop` over `cases` generated inputs; panic with the seed on failure.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!("property {name} failed (case {case}, seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_reproducible() {
        let mut a = Gen::new(1);
        let mut b = Gen::new(1);
        for _ in 0..100 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn wide_floats_cover_specials() {
        let mut g = Gen::new(2);
        let mut saw_zero = false;
        let mut saw_sub = false;
        for _ in 0..10_000 {
            let x = g.f32_wide();
            if x == 0.0 {
                saw_zero = true;
            }
            if x != 0.0 && x.abs() < f32::MIN_POSITIVE {
                saw_sub = true;
            }
            assert!(!x.is_nan());
        }
        assert!(saw_zero && saw_sub);
    }

    #[test]
    fn check_reports_failures() {
        let r = std::panic::catch_unwind(|| {
            check("always-fails", 3, |_| Err("nope".into()));
        });
        assert!(r.is_err());
    }

    #[test]
    fn check_passes_good_property() {
        check("tautology", 10, |g| {
            let x = g.f32_normalish(1.0);
            if x.is_finite() {
                Ok(())
            } else {
                Err(format!("{x}"))
            }
        });
    }
}
