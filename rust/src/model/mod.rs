//! Model metadata: the AOT manifest binding Rust to the lowered graphs.

pub mod manifest;
