//! `manifest.json` — the contract between the AOT pipeline and the
//! coordinator.
//!
//! `python/compile/aot.py` serializes the ordered variable table (name,
//! shape, kind, size) plus the static model/data hyper-parameters; the Rust
//! side binds HLO operands *by position* from this table. Variable `kind`
//! drives the paper's weight-matrices-only rule.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VarKind {
    Weight,
    Bias,
    NormScale,
    NormBias,
}

impl VarKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "weight" => VarKind::Weight,
            "bias" => VarKind::Bias,
            "norm_scale" => VarKind::NormScale,
            "norm_bias" => VarKind::NormBias,
            other => anyhow::bail!("unknown variable kind {other:?}"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct VarSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub kind: VarKind,
    pub size: usize,
}

/// Static model/data hyper-parameters baked into the lowered shapes.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub feature_dim: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub num_blocks: usize,
    pub streaming: bool,
    pub batch: usize,
    pub seq_len: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub config: ModelConfig,
    pub variables: Vec<VarSpec>,
    pub total_params: usize,
    /// artifact file names relative to the manifest directory
    pub artifacts: std::collections::BTreeMap<String, String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let cfg = j.req("config")?;
        let get_usize = |o: &Json, k: &str| -> Result<usize> {
            o.req(k)?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("{k} must be a non-negative integer"))
        };
        let config = ModelConfig {
            name: cfg
                .req("name")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("config.name must be a string"))?
                .to_string(),
            feature_dim: get_usize(cfg, "feature_dim")?,
            vocab: get_usize(cfg, "vocab")?,
            d_model: get_usize(cfg, "d_model")?,
            num_blocks: get_usize(cfg, "num_blocks")?,
            streaming: cfg
                .req("streaming")?
                .as_bool()
                .ok_or_else(|| anyhow::anyhow!("config.streaming must be a bool"))?,
            batch: get_usize(cfg, "batch")?,
            seq_len: get_usize(cfg, "seq_len")?,
        };
        let mut variables = Vec::new();
        for v in j
            .req("variables")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("variables must be an array"))?
        {
            let shape: Vec<usize> = v
                .req("shape")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("shape must be an array"))?
                .iter()
                .map(|d| {
                    d.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("shape dims must be ints"))
                })
                .collect::<Result<_>>()?;
            let size = get_usize(v, "size")?;
            anyhow::ensure!(
                shape.iter().product::<usize>() == size,
                "variable size mismatch"
            );
            variables.push(VarSpec {
                name: v
                    .req("name")?
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("variable name must be a string"))?
                    .to_string(),
                shape,
                kind: VarKind::parse(
                    v.req("kind")?
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("kind must be a string"))?,
                )?,
                size,
            });
        }
        let total_params = get_usize(&j, "total_params")?;
        anyhow::ensure!(
            variables.iter().map(|v| v.size).sum::<usize>() == total_params,
            "total_params does not match the variable table"
        );
        let mut artifacts = std::collections::BTreeMap::new();
        if let Some(Json::Obj(m)) = j.get("artifacts") {
            for (k, v) in m {
                if let Some(s) = v.as_str() {
                    artifacts.insert(k.clone(), s.to_string());
                }
            }
        }
        Ok(Manifest {
            config,
            variables,
            total_params,
            artifacts,
        })
    }

    pub fn num_vars(&self) -> usize {
        self.variables.len()
    }

    /// Fraction of parameters living in weight matrices (the Sec. 2.4
    /// observation; ~99.8% for the paper's Conformer).
    pub fn weight_fraction(&self) -> f64 {
        let w: usize = self
            .variables
            .iter()
            .filter(|v| v.kind == VarKind::Weight)
            .map(|v| v.size)
            .sum();
        w as f64 / self.total_params.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub const SAMPLE: &str = r#"{
        "config": {"name": "tiny", "feature_dim": 16, "vocab": 32,
                   "d_model": 32, "ff_mult": 4, "num_heads": 2,
                   "num_blocks": 1, "conv_kernel": 5, "gn_groups": 4,
                   "streaming": false, "batch": 4, "seq_len": 16},
        "num_variables": 2,
        "total_params": 20,
        "variables": [
            {"name": "w", "shape": [4, 4], "kind": "weight", "size": 16},
            {"name": "b", "shape": [4], "kind": "bias", "size": 4}
        ],
        "artifacts": {"init": "init.hlo.txt"},
        "interchange": "hlo-text"
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.config.name, "tiny");
        assert_eq!(m.config.batch, 4);
        assert_eq!(m.num_vars(), 2);
        assert_eq!(m.variables[0].kind, VarKind::Weight);
        assert_eq!(m.total_params, 20);
        assert_eq!(m.artifacts["init"], "init.hlo.txt");
        assert!((m.weight_fraction() - 0.8).abs() < 1e-9);
    }

    #[test]
    fn rejects_size_mismatch() {
        let bad = SAMPLE.replace("\"size\": 16", "\"size\": 15");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_unknown_kind() {
        let bad = SAMPLE.replace("\"weight\"", "\"mystery\"");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_config_key() {
        let bad = SAMPLE.replace("\"batch\": 4,", "");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_total_mismatch() {
        let bad = SAMPLE.replace("\"total_params\": 20", "\"total_params\": 21");
        assert!(Manifest::parse(&bad).is_err());
    }
}
