#!/usr/bin/env python3
"""Compare fresh BENCH_*.json results against committed baselines.

Reads every BENCH_*.json in --dir (default: cwd, where `cargo bench` with
OMC_BENCH_JSON=1 writes them) and compares per-case `median_ns` against
the same file under --baselines (default: benches/baselines/).

Two tiers:
  * suites named in --strict-suites (comma-separated, e.g. codec,pack,round)
    are a FAILING gate: any case slower than baseline by more than
    --strict-threshold (default 35%) exits 1 with a ::error:: annotation
    (slowdowns between --threshold and --strict-threshold still warn); a
    gated suite with no committed baseline only warns — the gate is
    dormant until a baseline is blessed, then arms automatically;
  * every other suite warns at --threshold (default 25%) and never fails
    (shared-runner noise), unless --strict promotes them all.

Bless the current numbers as the new baseline:
    python3 scripts/bench_trend.py --bless
(see benches/baselines/README.md for the full refresh workflow)

Exit codes: 0 = ok/warnings, 1 = gated regression (strict suite, or any
regression with --strict), 2 = usage/IO error (incl. malformed JSON).
"""

import argparse
import glob
import json
import os
import shutil
import sys


def load_cases(path):
    """Parse one BENCH_*.json into {case name: row}. Raises ValueError on
    malformed JSON or a non-object document — a gate must fail loudly, not
    silently skip a suite it cannot read."""
    with open(path) as fh:
        try:
            doc = json.load(fh)
        except json.JSONDecodeError as e:
            raise ValueError(f"{path}: malformed JSON ({e})") from e
    if not isinstance(doc, dict):
        raise ValueError(f"{path}: expected a JSON object")
    return {r["name"]: r for r in doc.get("results", []) if "name" in r}


def suite_name(filename):
    """BENCH_codec.json -> codec"""
    stem = os.path.basename(filename)
    if stem.startswith("BENCH_") and stem.endswith(".json"):
        return stem[len("BENCH_"):-len(".json")]
    return stem


def steady_state(row):
    """Whether a row's median was measured over at least one full
    steady-state pass. Under OMC_BENCH_FAST some suites emit rows whose
    measured `iters` fall below their `warmup_iters` — those medians
    sample the cold path (first-touch allocation, cache fill) and are
    not comparable against a steady baseline, so they must not arm a
    failing gate. Rows missing either field count as steady (older
    baselines predate the fields)."""
    iters = row.get("iters") or 0
    warmup = row.get("warmup_iters") or 0
    return iters >= max(1, warmup)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dir", default=".", help="where fresh BENCH_*.json live")
    ap.add_argument("--baselines", default="benches/baselines",
                    help="committed baseline directory")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="relative slowdown that triggers a warning")
    ap.add_argument("--strict-suites", default="",
                    help="comma-separated suite names gated as failures "
                         "(e.g. codec,pack,round)")
    ap.add_argument("--strict-threshold", type=float, default=0.35,
                    help="relative slowdown that FAILS a strict suite")
    ap.add_argument("--bless", action="store_true",
                    help="copy fresh results into the baseline directory")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on ANY regression (default: warn only "
                         "outside --strict-suites)")
    args = ap.parse_args()

    strict_suites = {s.strip() for s in args.strict_suites.split(",") if s.strip()}

    fresh_files = sorted(glob.glob(os.path.join(args.dir, "BENCH_*.json")))

    # A suite named in --strict-suites that produced no fresh BENCH_*.json
    # means the gated bench was skipped or crashed — that must FAIL the
    # gate, not silently pass because the comparison loop never saw it.
    if not args.bless:
        fresh_suites = {suite_name(f) for f in fresh_files}
        absent = sorted(strict_suites - fresh_suites)
        if absent:
            for s in absent:
                print(f"::error::bench-trend: gated suite '{s}' has no fresh "
                      f"BENCH_{s}.json under {args.dir} — the bench was "
                      f"skipped or crashed, which a strict gate must not "
                      f"silently pass")
            return 1

    if not fresh_files:
        print(f"bench-trend: no BENCH_*.json under {args.dir} — "
              f"run benches with OMC_BENCH_JSON=1 first")
        return 0

    if args.bless:
        os.makedirs(args.baselines, exist_ok=True)
        for f in fresh_files:
            dest = os.path.join(args.baselines, os.path.basename(f))
            shutil.copyfile(f, dest)
            print(f"blessed baseline: {dest}")
        return 0

    failures, warnings, improvements, missing = [], [], [], []
    for f in fresh_files:
        name = os.path.basename(f)
        suite = suite_name(f)
        # strict suites FAIL past strict-threshold but keep the ordinary
        # warning tier below it — a 30% codec slip still prints ::warning::.
        # --strict means "exit 1 on ANY regression", so it tightens gated
        # suites to the lower of the two thresholds rather than exempting
        # them.
        if suite in strict_suites:
            fail_threshold = args.strict_threshold
            if args.strict:
                fail_threshold = min(fail_threshold, args.threshold)
        elif args.strict:
            fail_threshold = args.threshold
        else:
            fail_threshold = None
        base_path = os.path.join(args.baselines, name)
        if not os.path.exists(base_path):
            # a gated suite without a committed baseline is warn-only — the
            # strict gate arms itself the moment a baseline is blessed
            missing.append((name, suite in strict_suites))
            continue
        try:
            fresh_cases = load_cases(f)
            base_cases = load_cases(base_path)
        except ValueError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        for case, fr in sorted(fresh_cases.items()):
            ba = base_cases.get(case)
            if not ba or not ba.get("median_ns") or not fr.get("median_ns"):
                continue
            ratio = fr["median_ns"] / ba["median_ns"]
            line = (f"{name}:{case}  baseline {ba['median_ns']:.0f}ns -> "
                    f"fresh {fr['median_ns']:.0f}ns  ({ratio:.2f}x)")
            if fail_threshold is not None and ratio > 1.0 + fail_threshold:
                if steady_state(fr) and steady_state(ba):
                    failures.append((fail_threshold, line))
                else:
                    # a cold-path median (iters < warmup_iters on either
                    # side) regressing past the gate is a warning, not a
                    # failure — the statistic itself is not comparable
                    warnings.append((fail_threshold,
                                     f"{line} [cold-path median: iters < "
                                     f"warmup_iters, gate demoted]"))
            elif ratio > 1.0 + args.threshold:
                warnings.append((args.threshold, line))
            elif ratio < 1.0 - args.threshold:
                improvements.append(line)

    for name, gated in missing:
        if gated:
            print(f"::warning::bench-trend: gated suite {name} has "
                  f"no committed baseline — the strict gate is dormant until "
                  f"one is blessed (`python3 scripts/bench_trend.py --bless` "
                  f"on a quiet machine)")
        else:
            print(f"bench-trend: no committed baseline for {name} — bless one "
                  f"with `python3 scripts/bench_trend.py --bless` on a quiet "
                  f"machine")
    for line in improvements:
        print(f"bench-trend: improvement: {line}")
    for threshold, line in warnings:
        # ::warning:: renders as a GitHub Actions annotation
        print(f"::warning::bench-trend >{int(threshold * 100)}% slowdown: {line}")
    for threshold, line in failures:
        print(f"::error::bench-trend >{int(threshold * 100)}% slowdown "
              f"(gated suite): {line}")
    if failures:
        return 1
    if not warnings and not missing:
        print(f"bench-trend: {len(fresh_files)} suite(s) within tolerance "
              f"(strict: {sorted(strict_suites) or 'none'} at "
              f"{int(args.strict_threshold * 100)}%, rest warn at "
              f"{int(args.threshold * 100)}%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
