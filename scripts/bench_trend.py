#!/usr/bin/env python3
"""Compare fresh BENCH_*.json results against committed baselines.

Reads every BENCH_*.json in --dir (default: cwd, where `cargo bench` with
OMC_BENCH_JSON=1 writes them) and compares per-case `median_ns` against
the same file under --baselines (default: benches/baselines/). A case
slower than baseline by more than --threshold (default 25%) prints a
warning — CI *warns, never fails* on this (shared-runner noise), unless
--strict is passed.

Bless the current numbers as the new baseline:
    python3 scripts/bench_trend.py --bless

Exit codes: 0 = ok/warnings (or regressions without --strict),
1 = regressions with --strict, 2 = usage error.
"""

import argparse
import glob
import json
import os
import shutil
import sys


def load_cases(path):
    with open(path) as fh:
        doc = json.load(fh)
    return {r["name"]: r for r in doc.get("results", []) if "name" in r}


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dir", default=".", help="where fresh BENCH_*.json live")
    ap.add_argument("--baselines", default="benches/baselines",
                    help="committed baseline directory")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="relative slowdown that triggers a warning")
    ap.add_argument("--bless", action="store_true",
                    help="copy fresh results into the baseline directory")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on regressions (default: warn only)")
    args = ap.parse_args()

    fresh_files = sorted(glob.glob(os.path.join(args.dir, "BENCH_*.json")))
    if not fresh_files:
        print(f"bench-trend: no BENCH_*.json under {args.dir} — "
              f"run benches with OMC_BENCH_JSON=1 first")
        return 0

    if args.bless:
        os.makedirs(args.baselines, exist_ok=True)
        for f in fresh_files:
            dest = os.path.join(args.baselines, os.path.basename(f))
            shutil.copyfile(f, dest)
            print(f"blessed baseline: {dest}")
        return 0

    regressions, improvements, missing = [], [], []
    for f in fresh_files:
        name = os.path.basename(f)
        base_path = os.path.join(args.baselines, name)
        if not os.path.exists(base_path):
            missing.append(name)
            continue
        fresh_cases = load_cases(f)
        base_cases = load_cases(base_path)
        for case, fr in sorted(fresh_cases.items()):
            ba = base_cases.get(case)
            if not ba or not ba.get("median_ns") or not fr.get("median_ns"):
                continue
            ratio = fr["median_ns"] / ba["median_ns"]
            line = (f"{name}:{case}  baseline {ba['median_ns']:.0f}ns -> "
                    f"fresh {fr['median_ns']:.0f}ns  ({ratio:.2f}x)")
            if ratio > 1.0 + args.threshold:
                regressions.append(line)
            elif ratio < 1.0 - args.threshold:
                improvements.append(line)

    for name in missing:
        print(f"bench-trend: no committed baseline for {name} — bless one with "
              f"`python3 scripts/bench_trend.py --bless` on a quiet machine")
    for line in improvements:
        print(f"bench-trend: improvement: {line}")
    if regressions:
        pct = int(args.threshold * 100)
        for line in regressions:
            # ::warning:: renders as a GitHub Actions annotation
            print(f"::warning::bench-trend >{pct}% slowdown: {line}")
        if args.strict:
            return 1
    if not regressions and not missing:
        print(f"bench-trend: {len(fresh_files)} suite(s) within "
              f"{int(args.threshold * 100)}% of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
