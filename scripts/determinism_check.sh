#!/usr/bin/env bash
# Byte-determinism gate for one sweep profile — the shared engine behind
# every leg of the CI determinism matrix (.github/workflows/ci.yml).
#
#   determinism_check.sh <profile> <out-prefix> [required-regex ...]
#
# Runs the profile four ways and requires all four sweep_summary.json
# files to be byte-identical:
#
#   <prefix>_seq_a   --sequential                  (reference run)
#   <prefix>_seq_b   --sequential                  (run-to-run)
#   <prefix>_pool    --workers 4                   (cell-pool scheduling)
#   <prefix>_scalar  --sequential, OMC_FORCE_SCALAR=1  (ISA dispatch)
#
# Any extra args are extended regexes that must match the reference
# summary — the liveness greps that keep the gate non-vacuous. The schema
# guarantees the counter keys exist on every cell, so a chaos smoke that
# injects no faults or a scale smoke whose churn never rejects a candidate
# can only show up as a silent zero; the greps turn that into a failure.
#
# Env:
#   OMC_BIN             sweep binary (default ./target/release/omc-fl)
#   OMC_RSS_CEILING_MB  if set, run the reference leg under GNU time -v
#                       and fail if peak RSS exceeds this many MB — the
#                       O(active)-memory gate for the 10^6-client scale
#                       profile (docs/SCALE.md)
#   OMC_TIME_BIN        GNU time binary (default /usr/bin/time)
#
# Exit codes: 0 = gate holds, 1 = determinism/liveness/RSS failure,
# 2 = usage error.
set -euo pipefail

if [ "$#" -lt 2 ]; then
  echo "usage: $0 <profile> <out-prefix> [required-regex ...]" >&2
  exit 2
fi

profile=$1
prefix=$2
shift 2
bin=${OMC_BIN:-./target/release/omc-fl}
time_bin=${OMC_TIME_BIN:-/usr/bin/time}

# ---- reference run (optionally RSS-metered) --------------------------------
if [ -n "${OMC_RSS_CEILING_MB:-}" ] && [ -x "$time_bin" ]; then
  if ! "$time_bin" -v "$bin" sweep --profile "$profile" --sequential \
      --out "${prefix}_seq_a" 2> "${prefix}_time.log"; then
    cat "${prefix}_time.log" >&2
    echo "::error::determinism($profile): reference run failed"
    exit 1
  fi
  peak_kb=$(awk -F': *' '/Maximum resident set size/ {print $2}' \
    "${prefix}_time.log")
  if [ -z "$peak_kb" ]; then
    echo "::warning::determinism($profile): $time_bin emitted no RSS line — ceiling not enforced"
  else
    ceiling_kb=$((OMC_RSS_CEILING_MB * 1024))
    echo "determinism($profile): peak RSS ${peak_kb} kB (ceiling ${ceiling_kb} kB)"
    if [ "$peak_kb" -gt "$ceiling_kb" ]; then
      echo "::error::determinism($profile): peak RSS ${peak_kb} kB exceeds the ${OMC_RSS_CEILING_MB} MB ceiling — the O(active) memory contract is broken"
      exit 1
    fi
  fi
else
  if [ -n "${OMC_RSS_CEILING_MB:-}" ]; then
    echo "::warning::determinism($profile): $time_bin not found — RSS ceiling skipped"
  fi
  "$bin" sweep --profile "$profile" --sequential --out "${prefix}_seq_a"
fi

# ---- the other three scheduling/ISA variants -------------------------------
"$bin" sweep --profile "$profile" --sequential --out "${prefix}_seq_b"
"$bin" sweep --profile "$profile" --workers 4 --out "${prefix}_pool"
OMC_FORCE_SCALAR=1 "$bin" sweep --profile "$profile" --sequential \
  --out "${prefix}_scalar"

# ---- byte identity ---------------------------------------------------------
ref="${prefix}_seq_a/sweep_summary.json"
for variant in seq_b pool scalar; do
  if ! cmp "$ref" "${prefix}_${variant}/sweep_summary.json"; then
    echo "::error::determinism($profile): sweep_summary.json differs between seq_a and ${variant}"
    exit 1
  fi
done
echo "determinism($profile): sweep_summary.json byte-identical across runs, scheduling, and ISA"

# ---- liveness greps --------------------------------------------------------
for re in "$@"; do
  if ! grep -Eq -- "$re" "$ref"; then
    echo "::error::determinism($profile): required counter pattern '$re' not found — the gate is vacuous"
    exit 1
  fi
done
if [ "$#" -gt 0 ]; then
  echo "determinism($profile): all $# liveness counters nonzero"
fi
