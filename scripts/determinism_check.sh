#!/usr/bin/env bash
# Byte-determinism gate for one sweep profile — the shared engine behind
# every leg of the CI determinism matrix (.github/workflows/ci.yml).
#
#   determinism_check.sh <profile> <out-prefix> [required-regex ...]
#
# Runs the profile four ways and requires all four sweep_summary.json
# files to be byte-identical:
#
#   <prefix>_seq_a   --sequential                  (reference run)
#   <prefix>_seq_b   --sequential                  (run-to-run)
#   <prefix>_pool    --workers 4                   (cell-pool scheduling)
#   <prefix>_scalar  --sequential, OMC_FORCE_SCALAR=1  (ISA dispatch)
#
# Any extra args are extended regexes that must match the reference
# summary — the liveness greps that keep the gate non-vacuous. The schema
# guarantees the counter keys exist on every cell, so a chaos smoke that
# injects no faults or a scale smoke whose churn never rejects a candidate
# can only show up as a silent zero; the greps turn that into a failure.
#
# Env:
#   OMC_BIN             sweep binary (default ./target/release/omc-fl)
#   OMC_RSS_CEILING_MB  if set, run the reference leg under the host's
#                       time binary (GNU `-v`, falling back to BSD/macOS
#                       `-l`) and fail if peak RSS exceeds this many MB —
#                       the O(active)-memory gate for the 10^6-client
#                       scale profile (docs/SCALE.md). A requested ceiling
#                       that cannot be metered is a hard FAILURE, never a
#                       silent skip.
#   OMC_TIME_BIN        time binary (default /usr/bin/time)
#
# Exit codes: 0 = gate holds, 1 = determinism/liveness/RSS failure,
# 2 = usage error.
set -euo pipefail

if [ "$#" -lt 2 ]; then
  echo "usage: $0 <profile> <out-prefix> [required-regex ...]" >&2
  exit 2
fi

profile=$1
prefix=$2
shift 2
bin=${OMC_BIN:-./target/release/omc-fl}
time_bin=${OMC_TIME_BIN:-/usr/bin/time}

# ---- reference run (optionally RSS-metered) --------------------------------
if [ -n "${OMC_RSS_CEILING_MB:-}" ]; then
  # A requested ceiling is enforced or the gate fails — a silent skip here
  # turns the O(active) memory contract vacuous. Probe which dialect the
  # host's time binary speaks: GNU `-v` reports
  # "Maximum resident set size (kbytes): N"; BSD/macOS `-l` reports
  # "N  maximum resident set size" in bytes.
  rss_flag=""
  rss_unit=""
  if [ -x "$time_bin" ]; then
    if "$time_bin" -v true >/dev/null 2>&1; then
      rss_flag="-v" rss_unit="kb"
    elif "$time_bin" -l true >/dev/null 2>&1; then
      rss_flag="-l" rss_unit="bytes"
    fi
  fi
  if [ -z "$rss_flag" ]; then
    echo "::error::determinism($profile): OMC_RSS_CEILING_MB is set but $time_bin speaks neither GNU -v nor BSD -l — the memory ceiling cannot be enforced"
    exit 1
  fi
  if ! "$time_bin" "$rss_flag" "$bin" sweep --profile "$profile" --sequential \
      --out "${prefix}_seq_a" 2> "${prefix}_time.log"; then
    cat "${prefix}_time.log" >&2
    echo "::error::determinism($profile): reference run failed"
    exit 1
  fi
  if [ "$rss_unit" = "kb" ]; then
    peak_raw=$(awk -F': *' '/Maximum resident set size/ {print $2}' \
      "${prefix}_time.log")
    peak_kb=${peak_raw:-}
  else
    peak_raw=$(awk '/maximum resident set size/ {print $1}' \
      "${prefix}_time.log")
    peak_kb=$(( ${peak_raw:-0} / 1024 ))
  fi
  if [ -z "$peak_raw" ]; then
    echo "::error::determinism($profile): $time_bin $rss_flag emitted no RSS line — the requested ceiling cannot be enforced"
    exit 1
  fi
  ceiling_kb=$((OMC_RSS_CEILING_MB * 1024))
  echo "determinism($profile): peak RSS ${peak_kb} kB (ceiling ${ceiling_kb} kB)"
  if [ "$peak_kb" -gt "$ceiling_kb" ]; then
    echo "::error::determinism($profile): peak RSS ${peak_kb} kB exceeds the ${OMC_RSS_CEILING_MB} MB ceiling — the O(active) memory contract is broken"
    exit 1
  fi
else
  "$bin" sweep --profile "$profile" --sequential --out "${prefix}_seq_a"
fi

# ---- the other three scheduling/ISA variants -------------------------------
"$bin" sweep --profile "$profile" --sequential --out "${prefix}_seq_b"
"$bin" sweep --profile "$profile" --workers 4 --out "${prefix}_pool"
OMC_FORCE_SCALAR=1 "$bin" sweep --profile "$profile" --sequential \
  --out "${prefix}_scalar"

# ---- byte identity ---------------------------------------------------------
ref="${prefix}_seq_a/sweep_summary.json"
for variant in seq_b pool scalar; do
  if ! cmp "$ref" "${prefix}_${variant}/sweep_summary.json"; then
    echo "::error::determinism($profile): sweep_summary.json differs between seq_a and ${variant}"
    exit 1
  fi
done
echo "determinism($profile): sweep_summary.json byte-identical across runs, scheduling, and ISA"

# ---- liveness greps --------------------------------------------------------
for re in "$@"; do
  if ! grep -Eq -- "$re" "$ref"; then
    echo "::error::determinism($profile): required counter pattern '$re' not found — the gate is vacuous"
    exit 1
  fi
done
if [ "$#" -gt 0 ]; then
  echo "determinism($profile): all $# liveness counters nonzero"
fi
