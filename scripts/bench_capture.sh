#!/usr/bin/env bash
# Capture a full bench baseline: run every suite with OMC_BENCH_JSON
# pointed at benches/baselines/, so scripts/bench_trend.py has committed
# numbers to diff against. Run on a quiet machine (ideally the CI runner
# class), then commit the BENCH_*.json files.
#
# Usage:
#   scripts/bench_capture.sh            # full budgets (~minutes)
#   OMC_BENCH_FAST=1 scripts/bench_capture.sh   # smoke budgets
set -euo pipefail
cd "$(dirname "$0")/.."

dest="benches/baselines"
mkdir -p "$dest"

benches=(bench_pack bench_quantize bench_transform bench_codec
         bench_round bench_sweep bench_native bench_async
         bench_delta bench_sparse bench_population bench_serve)

for b in "${benches[@]}"; do
  echo "== $b"
  OMC_BENCH_JSON="$dest" cargo bench --bench "$b"
done

echo "captured $(ls "$dest"/BENCH_*.json | wc -l) baseline file(s) in $dest/"
echo "review + commit them, then scripts/bench_trend.py diffs future runs"
