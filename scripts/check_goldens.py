#!/usr/bin/env python3
"""Diff a fresh sweep summary against a committed golden.

Comparison rules (per leaf value, by JSON type):
  * integers (byte/count fields)  -> exact match
  * floats                        -> relative tolerance (--rel-tol, 1e-6)
  * strings / bools / nulls       -> exact match
  * structure (keys, array len)   -> exact match

Exit codes: 0 = match (or golden missing without --strict-missing),
1 = mismatch, 2 = usage/IO error.

Workflows:
  check:   python3 scripts/check_goldens.py \
               --fresh results/sweep_smoke/sweep_summary.json \
               --golden goldens/sweep_smoke.json
  bless:   python3 scripts/check_goldens.py --bless \
               --fresh results/sweep_smoke/sweep_summary.json \
               --golden goldens/sweep_smoke.json
(or regenerate from Rust directly: `omc-fl sweep --profile smoke --bless`)
"""

import argparse
import json
import math
import os
import shutil
import sys


def walk_diff(golden, fresh, rel_tol, path="$"):
    """Yield (path, golden_value, fresh_value, reason) mismatch tuples."""
    if type(golden) is not type(fresh) and not (
        isinstance(golden, (int, float))
        and isinstance(fresh, (int, float))
        and not isinstance(golden, bool)
        and not isinstance(fresh, bool)
    ):
        yield (path, golden, fresh, "type mismatch")
        return
    if isinstance(golden, dict):
        for key in sorted(set(golden) | set(fresh)):
            if key not in golden:
                yield (f"{path}.{key}", "<absent>", fresh[key], "extra key")
            elif key not in fresh:
                yield (f"{path}.{key}", golden[key], "<absent>", "missing key")
            else:
                yield from walk_diff(golden[key], fresh[key], rel_tol, f"{path}.{key}")
    elif isinstance(golden, list):
        if len(golden) != len(fresh):
            yield (path, f"len {len(golden)}", f"len {len(fresh)}", "array length")
            return
        for i, (g, f) in enumerate(zip(golden, fresh)):
            yield from walk_diff(g, f, rel_tol, f"{path}[{i}]")
    elif isinstance(golden, bool) or golden is None or isinstance(golden, str):
        if golden != fresh:
            yield (path, golden, fresh, "value mismatch")
    elif isinstance(golden, int) and isinstance(fresh, int):
        # byte/count fields: exact
        if golden != fresh:
            yield (path, golden, fresh, "integer mismatch (exact field)")
    else:
        # at least one side is a float: relative tolerance
        g, f = float(golden), float(fresh)
        if math.isnan(g) and math.isnan(f):
            return
        if g == f:
            return
        denom = max(abs(g), abs(f))
        rel = abs(g - f) / denom if denom else 0.0
        if rel > rel_tol:
            yield (path, golden, fresh, f"float mismatch (rel {rel:.3e} > {rel_tol:g})")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", default="results/sweep_smoke/sweep_summary.json",
                    help="freshly generated sweep summary")
    ap.add_argument("--golden", default="goldens/sweep_smoke.json",
                    help="committed golden to compare against")
    ap.add_argument("--rel-tol", type=float, default=1e-6,
                    help="relative tolerance for float fields")
    ap.add_argument("--bless", action="store_true",
                    help="copy the fresh summary over the golden and exit")
    ap.add_argument("--strict-missing", action="store_true",
                    help="fail (instead of warn) when the golden is absent")
    ap.add_argument("--max-report", type=int, default=50,
                    help="cap on printed mismatches")
    args = ap.parse_args()

    if not os.path.exists(args.fresh):
        print(f"error: fresh summary {args.fresh} not found "
              f"(run `omc-fl sweep --profile smoke` first)", file=sys.stderr)
        return 2

    if args.bless:
        os.makedirs(os.path.dirname(args.golden) or ".", exist_ok=True)
        shutil.copyfile(args.fresh, args.golden)
        print(f"blessed: {args.fresh} -> {args.golden}")
        return 0

    if not os.path.exists(args.golden):
        msg = (f"golden {args.golden} not committed yet — bless it locally with\n"
               f"  python3 scripts/check_goldens.py --bless --fresh {args.fresh} "
               f"--golden {args.golden}\n"
               f"(or `omc-fl sweep --profile smoke --bless`) and commit the file")
        if args.strict_missing:
            print(f"error: {msg}", file=sys.stderr)
            return 1
        print(f"warning: {msg}")
        return 0

    try:
        with open(args.golden) as fh:
            golden = json.load(fh)
        with open(args.fresh) as fh:
            fresh = json.load(fh)
    except json.JSONDecodeError as e:
        # a gate must fail loudly on an unreadable artifact, not diff junk
        print(f"error: malformed JSON: {e}", file=sys.stderr)
        return 2

    # A schema_version bump invalidates every field-level diff below it:
    # the golden was blessed against a different summary shape, so the
    # walk would drown the real signal in added/removed-key noise. That
    # is a FAILING condition, not a warning — a schema bump must re-bless
    # the golden deliberately, never slide through CI as chatter.
    g_schema = golden.get("schema_version") if isinstance(golden, dict) else None
    f_schema = fresh.get("schema_version") if isinstance(fresh, dict) else None
    if g_schema is not None and f_schema is not None and g_schema != f_schema:
        print(f"error: schema_version bumped without --bless: golden "
              f"{args.golden} carries v{g_schema}, fresh summary carries "
              f"v{f_schema}. Re-bless the golden deliberately:\n"
              f"  python3 scripts/check_goldens.py --bless --fresh "
              f"{args.fresh} --golden {args.golden}", file=sys.stderr)
        return 1

    mismatches = list(walk_diff(golden, fresh, args.rel_tol))
    if not mismatches:
        print(f"goldens OK: {args.fresh} matches {args.golden} "
              f"(floats within rel {args.rel_tol:g}, ints exact)")
        return 0

    print(f"GOLDEN MISMATCH: {len(mismatches)} field(s) differ "
          f"({args.fresh} vs {args.golden})", file=sys.stderr)
    for path, g, f, reason in mismatches[: args.max_report]:
        print(f"  {path}: golden={g!r} fresh={f!r}  [{reason}]", file=sys.stderr)
    if len(mismatches) > args.max_report:
        print(f"  … and {len(mismatches) - args.max_report} more", file=sys.stderr)
    print("if the change is intentional, re-bless: "
          "python3 scripts/check_goldens.py --bless", file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
