//! **Sec. 3.4** — measured memory usage of the compressed parameter store.
//!
//! Paper: on Pixel 4 with FP16 (S1E5M10) parameters, OMC reduces peak
//! memory by 197 MB (38% of model size) for the streaming Conformer and by
//! 84 MB (45%) for a 3-block variant.
//!
//! Here we measure the same quantity on the runtime we have: the resident
//! bytes of a client's parameter store (bit-packed payloads + PVT scalars +
//! unquantized variables), both byte-accounted and observed live via the
//! process RSS while the stores are held, for two model sizes.
//!
//!     cargo run --release --example memory_footprint

use anyhow::Result;
use omc_fl::model::manifest::Manifest;
use omc_fl::omc::format::FloatFormat;
use omc_fl::omc::selection::SelectionPolicy;
use omc_fl::omc::store::{CompressedModel, StoredVar};
use omc_fl::util::cli::Args;
use omc_fl::util::rng::Xoshiro256pp;

/// Resident-set size of this process in bytes (Linux).
fn rss_bytes() -> usize {
    let statm = std::fs::read_to_string("/proc/self/statm").unwrap_or_default();
    let pages: usize = statm
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    pages * 4096
}

fn synthesize_params(manifest: &Manifest, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Xoshiro256pp::new(seed);
    manifest
        .variables
        .iter()
        .map(|v| {
            let mut buf = vec![0.0f32; v.size];
            rng.fill_normal(&mut buf, 0.05);
            buf
        })
        .collect()
}

fn measure(model_dir: &str, fmt: FloatFormat) -> Result<()> {
    let manifest = Manifest::load(std::path::Path::new(model_dir))?;
    let params = synthesize_params(&manifest, 7);
    let fp32_bytes = manifest.total_params * 4;
    let policy = SelectionPolicy::paper_default();
    let mask = policy.draw_mask(&manifest.variables, 1, 0, 0);

    // --- byte-accounted store sizes --------------------------------------
    let rss0 = rss_bytes();
    let fp32_store = CompressedModel::new(
        params.iter().map(|v| StoredVar::raw(v.clone())).collect(),
    );
    let rss_fp32 = rss_bytes();
    let omc_store = CompressedModel::new(
        params
            .iter()
            .zip(&mask)
            .map(|(v, &m)| {
                if m > 0.5 {
                    StoredVar::compress(v, fmt, true)
                } else {
                    StoredVar::raw(v.clone())
                }
            })
            .collect(),
    );
    let rss_omc = rss_bytes();

    let accounted_saving = fp32_store.memory_bytes() - omc_store.memory_bytes();
    println!(
        "\nmodel '{}' ({} params, model size {:.1} KB), format {}:",
        manifest.config.name,
        manifest.total_params,
        fp32_bytes as f64 / 1024.0,
        fmt
    );
    println!(
        "  FP32 store:   {:>10} bytes (accounted) | RSS delta {:>10} bytes",
        fp32_store.memory_bytes(),
        rss_fp32.saturating_sub(rss0)
    );
    println!(
        "  OMC store:    {:>10} bytes (accounted) | RSS delta {:>10} bytes",
        omc_store.memory_bytes(),
        rss_omc.saturating_sub(rss_fp32)
    );
    println!(
        "  saving:       {:>10} bytes = {:.0}% of model size (paper: 38%/45% at FP16)",
        accounted_saving,
        100.0 * accounted_saving as f64 / fp32_bytes as f64
    );
    // keep both stores alive until after the final RSS reads
    std::hint::black_box((&fp32_store, &omc_store));
    Ok(())
}

fn main() -> Result<()> {
    let mut args = Args::new(
        "memory_footprint",
        "Sec 3.4: compressed parameter-store memory, two model sizes at FP16",
    );
    args.flag("format", "storage format", Some("S1E5M10"));
    let m = args.parse();
    let fmt: FloatFormat = m.get("format").unwrap().parse()?;

    println!("## Sec. 3.4 — measured parameter-store memory (format {fmt})");
    // streaming model (the paper's production model analog)...
    measure("artifacts/small_streaming", fmt)?;
    // ...and a smaller variant (the paper's 3-block model analog)
    measure("artifacts/tiny", fmt)?;
    println!(
        "\nnote: expected saving at 90% PPQ = 0.9·(1 - {}/32)·weight_fraction; \
         the tiny model has a lower weight fraction, hence the smaller ratio —\n\
         the same reason the paper's 3-block model saves a different fraction.",
        fmt.bits()
    );
    Ok(())
}
