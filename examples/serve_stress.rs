//! **Serve stress** — open-loop wall-clock load against the serving
//! engine.
//!
//! The tables and the async bench measure the *planned* timeline; this
//! driver measures the *served* one: real worker threads training against
//! epoch-published snapshots, arena-pooled frames, and a bounded uplink
//! queue with admission accounting (docs/SERVING.md). It walks the
//! `presets::serve_ladder` — worker fan-out, the arena A/B, and a paced
//! open-loop rung — and per rung reports commits/sec, transport bytes/sec,
//! measured uplink p50/p99, queue high-water mark, rejected-and-readmitted
//! uplinks, and the frame-arena recycling ratio.
//!
//! Every rung is byte-compared against the planned-timeline reference
//! (`run_async_params_only`) before its row prints: wall-clock scheduling,
//! pooling, and backpressure must never leak into the committed model.
//!
//!     cargo run --release --example serve_stress -- --rounds 8
//!
//! Runs on `native:tiny` by default, so it needs no artifacts.

use anyhow::Result;
use omc_fl::coordinator::config::{ExperimentConfig, OmcConfig};
use omc_fl::coordinator::presets::{self, Scale};
use omc_fl::coordinator::Experiment;
use omc_fl::data::partition::Partition;
use omc_fl::fl::async_round::{AsyncConfig, StalenessPolicy};
use omc_fl::fl::serve::ServeConfig;
use omc_fl::util::cli::Args;

fn param_bits(exp: &Experiment) -> Vec<Vec<u32>> {
    exp.server
        .params
        .iter()
        .map(|v| v.iter().map(|x| x.to_bits()).collect())
        .collect()
}

fn main() -> Result<()> {
    let mut args = Args::new(
        "serve_stress",
        "wall-clock serving-engine load across the preset ladder",
    );
    args.flag("rounds", "commits per rung", Some("8"));
    args.flag("seed", "rng seed", Some("42"));
    args.flag(
        "model-dir",
        "model to serve (native:tiny needs no artifacts)",
        Some("native:tiny"),
    );
    args.flag("format", "OMC storage format", Some("S1E4M14"));
    let m = args.parse();
    let scale = Scale::from_flags(m.get_usize("rounds")?, m.get_u64("seed")?);
    let model_dir = m.get("model-dir").unwrap();
    let omc = OmcConfig {
        format: m.get("format").unwrap().parse()?,
        use_pvt: true,
        weights_only: true,
        fraction: 1.0,
        integrity: false,
    };
    let out = "results/serve_stress";

    let engine = omc_fl::runtime::engine::Engine::cpu()?;
    let model = presets::bind_model(&engine, model_dir)?;

    let cfg = |label: &str, serve: ServeConfig| -> ExperimentConfig {
        let mut c = presets::experiment(
            label,
            model_dir,
            &scale,
            Partition::BySpeaker,
            0,
            omc,
            out,
        );
        c.async_cfg = AsyncConfig {
            enabled: true,
            concurrency: 8,
            buffer_k: 4,
            policy: StalenessPolicy::Polynomial { alpha: 0.5 },
            max_staleness: usize::MAX,
            snapshot_ring: 4,
        };
        c.serve = serve;
        c
    };

    // the planned-timeline yardstick every rung's commits are held to
    let mut reference =
        Experiment::prepare_with_model(cfg("serve_ref", ServeConfig::default()), model.clone())?;
    reference.run_async_params_only()?;
    let ref_bits = param_bits(&reference);

    println!(
        "\n## Serve stress — {} commits per rung, {} over {}\n",
        scale.rounds,
        m.get("format").unwrap(),
        model_dir
    );
    println!(
        "| {:<30} | {:>9} | {:>10} | {:>8} | {:>8} | {:>6} | {:>8} | {:>13} |",
        "", "commits/s", "bytes/s", "p50 ms", "p99 ms", "peak q", "rejected", "arena f/r"
    );
    println!(
        "|{}|{}|{}|{}|{}|{}|{}|{}|",
        "-".repeat(32),
        "-".repeat(11),
        "-".repeat(12),
        "-".repeat(10),
        "-".repeat(10),
        "-".repeat(8),
        "-".repeat(10),
        "-".repeat(15)
    );

    for (label, serve) in presets::serve_ladder() {
        let mut exp = Experiment::prepare_with_model(cfg(&label, serve), model.clone())?;
        let (_, report) = exp.run_serve()?;
        assert_eq!(
            param_bits(&exp),
            ref_bits,
            "rung '{label}' diverged from the planned timeline"
        );
        println!(
            "| {:<30} | {:>9.2} | {:>10.0} | {:>8.2} | {:>8.2} | {:>6} | {:>8} | {:>6}/{:<6} |",
            label,
            report.commits_per_sec(),
            report.bytes_per_sec(),
            report.uplink_p50_s * 1e3,
            report.uplink_p99_s * 1e3,
            report.queue_peak_depth,
            report.rejected_total(),
            report.frame_arena.fresh,
            report.frame_arena.recycled,
        );
    }
    println!(
        "\nevery rung's committed parameters are bit-identical to the \
         planned-timeline reference; per-commit rows stream to \
         {out}/*_serve_commits.csv"
    );
    Ok(())
}
