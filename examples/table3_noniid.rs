//! **Table 3** — Non-streaming Conformer on *non-IID* LibriSpeech
//! (partitioned by speaker), FP32 vs OMC S1E4M14.
//!
//! The paper's point: OMC attains comparable WERs even under non-IID
//! client distributions. Here the non-IID axis is the per-speaker channel
//! vectors: each client owns a disjoint speaker shard.
//!
//! Thin wrapper over `presets::table3_grid` — identical to
//! `omc-fl sweep --preset table3`.
//!
//!     cargo run --release --example table3_noniid -- --rounds 80

use anyhow::Result;
use omc_fl::coordinator::presets::{self, Scale};
use omc_fl::coordinator::sweep::{self, SweepOptions};
use omc_fl::metrics::sweep::CellView;
use omc_fl::runtime::engine::Engine;
use omc_fl::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::new("table3", "Table 3: FP32 vs OMC S1E4M14 on non-IID data");
    args.flag("rounds", "federated rounds", Some("80"));
    args.flag("seed", "sweep seed", Some("42"));
    args.flag("model-dir", "artifact dir (or native:tiny)", Some("artifacts/small"));
    let m = args.parse();
    let scale = Scale::from_flags(m.get_usize("rounds")?, m.get_u64("seed")?);
    let spec = presets::table3_grid(m.get("model-dir").unwrap(), &scale)?;

    let engine = Engine::cpu()?;
    let report = sweep::run_sweep(&engine, &spec, &SweepOptions::default())?;
    sweep::print_report(
        "Table 3 — non-streaming conformer-lite on NON-IID (by-speaker) synthetic ASR",
        &report,
    );
    let wer = |i: usize| CellView(&report.cells[i].cell_json).final_wer();
    println!(
        "WER gap |OMC - FP32| = {:.2} points (paper: ~0 on non-IID too)",
        (wer(1) - wer(0)).abs()
    );
    println!("per-cell logs: {}/cells/*.csv", spec.output_dir.display());
    Ok(())
}
