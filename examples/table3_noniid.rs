//! **Table 3** — Non-streaming Conformer on *non-IID* LibriSpeech
//! (partitioned by speaker), FP32 vs OMC S1E4M14.
//!
//! The paper's point: OMC attains comparable WERs even under non-IID client
//! distributions. Here the non-IID axis is the per-speaker channel vectors:
//! each client owns a disjoint speaker shard.
//!
//!     cargo run --release --example table3_noniid -- --rounds 80

use anyhow::Result;
use omc_fl::coordinator::config::OmcConfig;
use omc_fl::coordinator::experiment::print_table;
use omc_fl::coordinator::presets::{self, Scale};
use omc_fl::data::partition::Partition;
use omc_fl::runtime::engine::Engine;
use omc_fl::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::new("table3", "Table 3: FP32 vs OMC S1E4M14 on non-IID data");
    args.flag("rounds", "federated rounds", Some("80"));
    args.flag("seed", "rng seed", Some("42"));
    args.flag("model-dir", "artifact dir", Some("artifacts/small"));
    let m = args.parse();
    let scale = Scale::from_flags(m.get_usize("rounds")?, m.get_u64("seed")?);
    let model_dir = m.get("model-dir").unwrap();
    let out = "results/table3";

    let engine = Engine::cpu()?;
    let model = presets::bind_model(&engine, model_dir)?;

    let mut rows = Vec::new();
    for (label, omc) in [
        ("FP32 (S1E8M23)", OmcConfig::fp32_baseline()),
        ("OMC (S1E4M14)", OmcConfig::paper("S1E4M14".parse()?)),
    ] {
        let cfg = presets::experiment(
            label,
            model_dir,
            &scale,
            Partition::BySpeaker,
            0,
            omc,
            out,
        );
        let (_, summary) = presets::run_variant(&model, cfg)?;
        rows.push(summary);
    }

    print_table(
        "Table 3 — non-streaming conformer-lite on NON-IID (by-speaker) synthetic ASR",
        &rows,
    );
    println!(
        "WER gap |OMC - FP32| = {:.2} points (paper: ~0 on non-IID too)",
        (rows[1].final_wer - rows[0].final_wer).abs()
    );
    println!("per-round logs: {out}/*.csv");
    Ok(())
}
