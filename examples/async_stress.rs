//! **Async stress** — buffered staleness-aware aggregation vs synchronous
//! rounds under a straggler-heavy cohort.
//!
//! Synchronous FedAvg pays for every straggler: the round closes at the
//! deadline no matter how early the fast clients reported. The async
//! engine (`fl::async_round`) keeps a fixed number of clients in flight
//! and commits every K buffered updates with a staleness discount, so the
//! *virtual* wall-clock per model version tracks the fast clients instead
//! of the slow tail. This driver runs the paper's OMC configuration
//! through the `presets::async_ladder` scenarios and reports, per rung:
//! final WER, mean update staleness, mean buffer occupancy, uplink bytes
//! discarded as too stale, the compressed snapshot-ring memory, and the
//! virtual time the run needed for its commits.
//!
//!     cargo run --release --example async_stress -- --rounds 40

use anyhow::Result;
use omc_fl::coordinator::config::OmcConfig;
use omc_fl::coordinator::experiment::human_bytes;
use omc_fl::coordinator::presets::{self, Scale};
use omc_fl::data::partition::Partition;
use omc_fl::runtime::engine::Engine;
use omc_fl::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::new(
        "async_stress",
        "buffered async aggregation vs sync rounds under stragglers",
    );
    args.flag("rounds", "commits (sync: rounds) per scenario", Some("40"));
    args.flag("seed", "rng seed", Some("42"));
    args.flag("model-dir", "artifact dir", Some("artifacts/small"));
    args.flag("format", "OMC storage format", Some("S1E4M14"));
    let m = args.parse();
    let scale = Scale::from_flags(m.get_usize("rounds")?, m.get_u64("seed")?);
    let model_dir = m.get("model-dir").unwrap();
    let omc = OmcConfig::paper(m.get("format").unwrap().parse()?);
    let out = "results/async_stress";

    let engine = Engine::cpu()?;
    let model = presets::bind_model(&engine, model_dir)?;

    println!(
        "\n## Async stress — OMC {} under a straggler cohort (mean 2s)\n",
        m.get("format").unwrap()
    );
    println!(
        "| {:<38} | {:>7} | {:>9} | {:>9} | {:>11} | {:>9} | {:>10} |",
        "", "WER", "staleness", "buffer", "wasted up", "ring", "virtual s"
    );
    println!(
        "|{}|{}|{}|{}|{}|{}|{}|",
        "-".repeat(40),
        "-".repeat(9),
        "-".repeat(11),
        "-".repeat(11),
        "-".repeat(13),
        "-".repeat(11),
        "-".repeat(12)
    );

    for (label, acfg) in presets::async_ladder() {
        let mut cfg = presets::experiment(
            &label,
            model_dir,
            &scale,
            // by-speaker shards vary the example counts the weighted
            // FedAvg (and the staleness discounts) renormalize over
            Partition::BySpeaker,
            0,
            omc,
            out,
        );
        // the same straggler model for every rung: the sync rung pays the
        // 4s reporting deadline, the async rungs replace it with staleness
        cfg.cohort.straggler_mean_s = 2.0;
        cfg.cohort.deadline_s = 4.0;
        cfg.cohort.weight_by_examples = true;
        cfg.async_cfg = acfg;
        let (rec, summary) = presets::run_variant(&model, cfg)?;
        if rec.is_async() {
            println!(
                "| {:<38} | {:>6.2}% | {:>9.2} | {:>9.2} | {:>11} | {:>9} | {:>10.1} |",
                label,
                summary.final_wer,
                rec.mean_staleness(),
                rec.mean_buffer_occupancy(),
                human_bytes(rec.total_discarded_bytes()),
                human_bytes(rec.last_ring_bytes()),
                rec.final_virtual_time(),
            );
        } else {
            println!(
                "| {:<38} | {:>6.2}% | {:>9} | {:>9} | {:>11} | {:>9} | {:>10} |",
                label,
                summary.final_wer,
                "-",
                "-",
                human_bytes(rec.total_up_bytes_discarded()),
                "-",
                "-",
            );
        }
    }
    println!(
        "\nper-commit logs (staleness hist, occupancy, drift): {out}/*_commits.csv"
    );
    println!("semantics and determinism contract: docs/ASYNC.md");
    Ok(())
}
