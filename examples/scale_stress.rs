//! **Scale stress** — OMC rounds over fleets the tables could never
//! enumerate.
//!
//! The tables materialize their whole fleet (32 clients). Production
//! cross-device FL registers millions of devices of which only a cohort's
//! worth train per round. This driver runs the paper's OMC configuration
//! up the `presets::scale_ladder`: from the enumerable reference fleet
//! through 10^5/10^6 registered clients to 10^7 clients behind eight edge
//! aggregators with churn and a diurnal availability wave. Per rung it
//! reports final WER, the analytic active-fleet estimate, churn/wave
//! rejection counts from the streaming sampler, edge→root uplink bytes,
//! and speed.
//!
//! Peak memory stays O(active cohort) at every rung: per-client dropout,
//! latency, device class, and dataset shard derive lazily from
//! `(seed, cid)` and are never materialized (docs/SCALE.md). Training
//! metrics differ across rungs only through *which* clients the sampler
//! draws — the per-client math is the same code path as the tables.
//!
//!     cargo run --release --example scale_stress -- --rounds 8
//!
//! Keep `--rounds` modest: every sampled client still trains for real.

use anyhow::Result;
use omc_fl::coordinator::config::OmcConfig;
use omc_fl::coordinator::experiment::human_bytes;
use omc_fl::coordinator::presets::{self, Scale};
use omc_fl::data::partition::Partition;
use omc_fl::runtime::engine::Engine;
use omc_fl::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::new(
        "scale_stress",
        "OMC rounds over lazy 10^5–10^7-client fleets with edge aggregation",
    );
    args.flag("rounds", "federated rounds per scenario", Some("8"));
    args.flag("seed", "rng seed", Some("42"));
    args.flag("model-dir", "artifact dir", Some("artifacts/small"));
    args.flag("format", "OMC storage format", Some("S1E4M14"));
    let m = args.parse();
    let scale = Scale::from_flags(m.get_usize("rounds")?, m.get_u64("seed")?);
    let model_dir = m.get("model-dir").unwrap();
    let omc = OmcConfig::paper(m.get("format").unwrap().parse()?);
    let out = "results/scale_stress";

    let engine = Engine::cpu()?;
    let model = presets::bind_model(&engine, model_dir)?;

    println!(
        "\n## Scale stress — OMC {} over lazy registered fleets\n",
        m.get("format").unwrap()
    );
    println!(
        "| {:<38} | {:>7} | {:>12} | {:>7} | {:>7} | {:>12} | {:>10} |",
        "", "WER", "active est.", "churn", "wave", "edge uplink", "rounds/min"
    );
    println!(
        "|{}|{}|{}|{}|{}|{}|{}|",
        "-".repeat(40),
        "-".repeat(9),
        "-".repeat(14),
        "-".repeat(9),
        "-".repeat(9),
        "-".repeat(14),
        "-".repeat(12)
    );

    for (label, population) in presets::scale_ladder() {
        let mut cfg = presets::experiment(
            &label,
            model_dir,
            &scale,
            // by-speaker shards exercise the lazy shard lookup: a client's
            // speakers derive from its cid without building the dense map
            Partition::BySpeaker,
            0,
            omc,
            out,
        );
        cfg.population = population;
        let (rec, summary) = presets::run_variant(&model, cfg)?;
        let (active, churn, wave, edge_up) = if rec.is_population() {
            (
                format!("{:.0}", rec.mean_active_estimate()),
                rec.total_churn_rejections().to_string(),
                rec.total_wave_rejections().to_string(),
                human_bytes(rec.total_edge_up_bytes() as usize),
            )
        } else {
            ("-".into(), "-".into(), "-".into(), "-".into())
        };
        println!(
            "| {:<38} | {:>6.2}% | {:>12} | {:>7} | {:>7} | {:>12} | {:>10.1} |",
            label,
            summary.final_wer,
            active,
            churn,
            wave,
            edge_up,
            summary.rounds_per_min,
        );
    }
    println!(
        "\nper-round population logs (attempts/rejections/class/edge columns): \
         {out}/*_population.csv"
    );
    Ok(())
}
