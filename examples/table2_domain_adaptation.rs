//! **Table 2** — Streaming Conformer on the Multi-Domain dataset
//! (domain adaptation: non-MF → MF).
//!
//! Paper rows: before-adaptation WER; FP32; OMC S1E3M7 (matches FP32 at
//! 41% memory); OMC S1E2M3 (worse WER but still better than
//! before-adaptation, at 29%).
//!
//! Thin wrapper over `presets::table2_grid` — identical to
//! `omc-fl sweep --preset table2`. The sweep pretrains on source domain 0
//! into a shared checkpoint, then runs every adaptation cell from it; the
//! before-adaptation probe is a direct evaluation of that checkpoint.
//!
//!     cargo run --release --example table2_domain_adaptation -- --rounds 60

use anyhow::Result;
use omc_fl::coordinator::presets::{self, Scale};
use omc_fl::coordinator::sweep::{self, SweepOptions};
use omc_fl::coordinator::Experiment;
use omc_fl::metrics::sweep::CellView;
use omc_fl::runtime::engine::Engine;
use omc_fl::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::new(
        "table2",
        "Table 2: domain adaptation with the streaming model (FP32 / S1E3M7 / S1E2M3)",
    );
    args.flag("pretrain-rounds", "rounds on the source domain", Some("60"));
    args.flag("rounds", "adaptation rounds per variant", Some("60"));
    args.flag("seed", "sweep seed", Some("42"));
    args.flag(
        "model-dir",
        "artifact dir (or native:tiny)",
        Some("artifacts/small_streaming"),
    );
    let m = args.parse();
    let scale = Scale::from_flags(m.get_usize("rounds")?, m.get_u64("seed")?);
    let model_dir = m.get("model-dir").unwrap();
    let spec = presets::table2_grid(
        model_dir,
        &scale,
        m.get_usize("pretrain-rounds")?,
    )?;

    let engine = Engine::cpu()?;
    let report = sweep::run_sweep(&engine, &spec, &SweepOptions::default())?;

    // before-adaptation probe: evaluate the pretrained checkpoint on the
    // target domain without any training, reusing the sweep's bound model
    // (a fresh binding would recompile the eval graph under PJRT)
    let pre = spec.pretrain.as_ref().expect("table2 pretrains");
    let mut probe_cfg = spec.cells[0].clone();
    probe_cfg.name = "before_adaptation".into();
    probe_cfg.init_from = pre.save_to.clone();
    let model = report
        .model_for(&probe_cfg.model_dir)
        .expect("sweep bound the model dir");
    let probe = Experiment::prepare_with_model(probe_cfg, model)?;
    let (before_wer, _) = probe.evaluate()?;
    drop(probe);

    println!("\nBefore Adaptation WER: {before_wer:.2}%");
    sweep::print_report(
        "Table 2 — streaming conformer-lite, domain adaptation (WER on target domain)",
        &report,
    );
    let cell = |i: usize| CellView(&report.cells[i].cell_json);
    println!(
        "shape checks: S1E3M7 ≈ FP32 ({:.2} vs {:.2}); S1E2M3 ({:.2}) worse than \
         S1E3M7 but better than before-adaptation ({before_wer:.2}); memory 41%/29% of FP32 \
         (paper) vs {:.0}%/{:.0}% here",
        cell(1).final_wer(),
        cell(0).final_wer(),
        cell(2).final_wer(),
        100.0 * cell(1).memory_ratio(),
        100.0 * cell(2).memory_ratio(),
    );
    println!("per-cell logs: {}/cells/*.csv", spec.output_dir.display());
    Ok(())
}
