//! **Table 2** — Streaming Conformer on the Multi-Domain dataset
//! (domain adaptation: non-MF → MF).
//!
//! Paper rows: before-adaptation WER; FP32; OMC S1E3M7 (matches FP32 at 41%
//! memory); OMC S1E2M3 (worse WER but still better than before-adaptation,
//! at 29%).
//!
//! Here: the *streaming* conformer-lite (`artifacts/small_streaming`,
//! causal attention + causal conv) is pretrained on synthetic domain 0,
//! then adapted to domain 1 under each compression setting.
//!
//!     cargo run --release --example table2_domain_adaptation -- --rounds 60

use anyhow::Result;
use omc_fl::coordinator::config::OmcConfig;
use omc_fl::coordinator::experiment::{print_table, Experiment};
use omc_fl::coordinator::presets::{self, Scale};
use omc_fl::data::partition::Partition;
use omc_fl::runtime::engine::Engine;
use omc_fl::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::new(
        "table2",
        "Table 2: domain adaptation with the streaming model (FP32 / S1E3M7 / S1E2M3)",
    );
    args.flag("pretrain-rounds", "rounds on the source domain", Some("60"));
    args.flag("rounds", "adaptation rounds per variant", Some("60"));
    args.flag("seed", "rng seed", Some("42"));
    args.flag("model-dir", "artifact dir", Some("artifacts/small_streaming"));
    let m = args.parse();
    let scale = Scale::from_flags(m.get_usize("rounds")?, m.get_u64("seed")?);
    let model_dir = m.get("model-dir").unwrap();
    let out = "results/table2";
    let ckpt = std::path::PathBuf::from(out).join("pretrained.bin");

    let engine = Engine::cpu()?;
    let model = presets::bind_model(&engine, model_dir)?;

    // ---- phase 1: pretrain on the source domain (the "non-MF" analog) ----
    let mut pre_cfg = presets::experiment(
        "pretrain_domain0",
        model_dir,
        &Scale::from_flags(m.get_usize("pretrain-rounds")?, scale.seed),
        Partition::Iid,
        0,
        OmcConfig::fp32_baseline(),
        out,
    );
    pre_cfg.save_to = Some(ckpt.clone());
    println!("== pretraining on source domain (FP32) ==");
    presets::run_variant(&model, pre_cfg)?;

    // ---- before-adaptation WER on the target domain ----------------------
    let mut probe_cfg = presets::experiment(
        "before_adaptation",
        model_dir,
        &Scale::from_flags(1, scale.seed),
        Partition::Iid,
        1,
        OmcConfig::fp32_baseline(),
        out,
    );
    probe_cfg.init_from = Some(ckpt.clone());
    let probe = Experiment::prepare_with_model(probe_cfg, model.clone())?;
    let (before_wer, _) = probe.evaluate()?;
    drop(probe);

    // ---- phase 2: adaptation on the target domain under each format ------
    let variants = [
        ("FP32 (S1E8M23)", OmcConfig::fp32_baseline()),
        ("OMC (S1E3M7)", OmcConfig::paper("S1E3M7".parse()?)),
        ("OMC (S1E2M3)", OmcConfig::paper("S1E2M3".parse()?)),
    ];
    let mut rows = Vec::new();
    for (label, omc) in variants {
        let mut cfg = presets::experiment(
            label, model_dir, &scale, Partition::Iid, 1, omc, out,
        );
        cfg.init_from = Some(ckpt.clone());
        // adaptation uses a lower lr, as finetuning does
        cfg.lr = 0.05;
        println!("== adapting to target domain: {label} ==");
        let (_, summary) = presets::run_variant(&model, cfg)?;
        rows.push(summary);
    }

    println!("\nBefore Adaptation WER: {before_wer:.2}%");
    print_table(
        "Table 2 — streaming conformer-lite, domain adaptation (WER on target domain)",
        &rows,
    );
    println!(
        "shape checks: S1E3M7 ≈ FP32 ({:.2} vs {:.2}); S1E2M3 ({:.2}) worse than \
         S1E3M7 but better than before-adaptation ({:.2}); memory 41%/29% of FP32 \
         (paper) vs {:.0}%/{:.0}% here",
        rows[1].final_wer,
        rows[0].final_wer,
        rows[2].final_wer,
        before_wer,
        100.0 * rows[1].memory_ratio,
        100.0 * rows[2].memory_ratio,
    );
    println!("per-round logs: {out}/*.csv");
    Ok(())
}
