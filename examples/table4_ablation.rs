//! **Table 4** — ablation: each proposed method applied sequentially at
//! S1E3M7 on the domain-adaptation workload.
//!
//! Paper ladder (WER): FP32 4.6 → quant-only 6.9 → +PVT 6.5 →
//! +weights-only 4.7 → +90% 4.6. The shape to reproduce: quantization alone
//! opens a WER gap; PVT, weights-only and PPQ close it monotonically back
//! to the baseline.
//!
//!     cargo run --release --example table4_ablation -- --rounds 50

use anyhow::Result;
use omc_fl::coordinator::config::OmcConfig;
use omc_fl::coordinator::experiment::print_table;
use omc_fl::coordinator::presets::{self, Scale};
use omc_fl::data::partition::Partition;
use omc_fl::runtime::engine::Engine;
use omc_fl::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::new("table4", "Table 4: OMC method ablation at S1E3M7");
    args.flag("pretrain-rounds", "rounds on the source domain", Some("60"));
    args.flag("rounds", "adaptation rounds per row", Some("50"));
    args.flag("seed", "rng seed", Some("42"));
    args.flag("format", "quantization format", Some("S1E3M7"));
    args.flag("model-dir", "artifact dir", Some("artifacts/small_streaming"));
    let m = args.parse();
    let scale = Scale::from_flags(m.get_usize("rounds")?, m.get_u64("seed")?);
    let model_dir = m.get("model-dir").unwrap();
    let out = "results/table4";
    let ckpt = std::path::PathBuf::from(out).join("pretrained.bin");

    let engine = Engine::cpu()?;
    let model = presets::bind_model(&engine, model_dir)?;

    // shared pretraining checkpoint (source domain, FP32)
    let mut pre_cfg = presets::experiment(
        "pretrain_domain0",
        model_dir,
        &Scale::from_flags(m.get_usize("pretrain-rounds")?, scale.seed),
        Partition::Iid,
        0,
        OmcConfig::fp32_baseline(),
        out,
    );
    pre_cfg.save_to = Some(ckpt.clone());
    println!("== pretraining on source domain (FP32) ==");
    presets::run_variant(&model, pre_cfg)?;

    let mut rows = Vec::new();
    for (label, omc) in presets::table4_ladder(m.get("format").unwrap())? {
        let mut cfg = presets::experiment(
            &label, model_dir, &scale, Partition::Iid, 1, omc, out,
        );
        cfg.init_from = Some(ckpt.clone());
        cfg.lr = 0.05;
        println!("== ablation row: {label} ==");
        let (_, summary) = presets::run_variant(&model, cfg)?;
        rows.push(summary);
    }

    print_table(
        "Table 4 — ablation: proposed methods applied sequentially (adaptation WER)",
        &rows,
    );
    println!("shape check (paper): FP32 {:.2} <= full OMC {:.2} << quant-only {:.2};",
        rows[0].final_wer, rows[4].final_wer, rows[1].final_wer);
    println!(
        "ladder: quant-only {:.2} -> +PVT {:.2} -> +weights-only {:.2} -> +90% {:.2}",
        rows[1].final_wer, rows[2].final_wer, rows[3].final_wer, rows[4].final_wer
    );
    println!("per-round logs: {out}/*.csv");
    Ok(())
}
