//! **Table 4** — ablation: each proposed method applied sequentially at
//! S1E3M7 on the domain-adaptation workload.
//!
//! Paper ladder (WER): FP32 4.6 → quant-only 6.9 → +PVT 6.5 →
//! +weights-only 4.7 → +90% 4.6. The shape to reproduce: quantization
//! alone opens a WER gap; PVT, weights-only and PPQ close it monotonically
//! back to the baseline.
//!
//! Thin wrapper over `presets::table4_grid` — identical to
//! `omc-fl sweep --preset table4`.
//!
//!     cargo run --release --example table4_ablation -- --rounds 50

use anyhow::Result;
use omc_fl::coordinator::presets::{self, Scale};
use omc_fl::coordinator::sweep::{self, SweepOptions};
use omc_fl::runtime::engine::Engine;
use omc_fl::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::new("table4", "Table 4: OMC method ablation at S1E3M7");
    args.flag("pretrain-rounds", "rounds on the source domain", Some("60"));
    args.flag("rounds", "adaptation rounds per row", Some("50"));
    args.flag("seed", "sweep seed", Some("42"));
    args.flag("format", "quantization format", Some("S1E3M7"));
    args.flag(
        "model-dir",
        "artifact dir (or native:tiny)",
        Some("artifacts/small_streaming"),
    );
    let m = args.parse();
    let scale = Scale::from_flags(m.get_usize("rounds")?, m.get_u64("seed")?);
    let spec = presets::table4_grid(
        m.get("model-dir").unwrap(),
        &scale,
        m.get_usize("pretrain-rounds")?,
        m.get("format").unwrap(),
    )?;

    let engine = Engine::cpu()?;
    let report = sweep::run_sweep(&engine, &spec, &SweepOptions::default())?;
    sweep::print_report(
        &format!(
            "Table 4 — ablation ladder at {} (adaptation workload)",
            m.get("format").unwrap()
        ),
        &report,
    );
    println!(
        "shape check: the ladder should close the quant-only WER gap \
         monotonically back toward the FP32 row"
    );
    println!("per-cell logs: {}/cells/*.csv", spec.output_dir.display());
    Ok(())
}
