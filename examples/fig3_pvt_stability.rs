//! **Figure 3** — per-variable transformation stabilizes from-scratch
//! training.
//!
//! Paper: training the non-streaming Conformer from scratch at S1E5M10
//! *without* PVT is unstable — WER decreases, then climbs after ~12K
//! rounds; with PVT it keeps decreasing.
//!
//! Scale substitution (documented in DESIGN.md §2/§5): 12K-round horizons
//! are out of reach on this testbed, so the error-accumulation mechanism is
//! surfaced at small scale with a coarser format (default S1E3M4,
//! all-parameter quantization — the regime where the unconditioned
//! quantizer bias actually bites within ~100 rounds). The *comparison*
//! (with-PVT stays stable and strictly better) is the reproduced shape.
//!
//! Thin wrapper over `presets::fig3_grid` — identical to
//! `omc-fl sweep --preset fig3`. Curves print from the cells'
//! deterministic `eval_wer_curve` summaries.
//!
//!     cargo run --release --example fig3_pvt_stability -- --rounds 100

use anyhow::Result;
use omc_fl::coordinator::presets::{self, Scale};
use omc_fl::coordinator::sweep::{self, SweepOptions};
use omc_fl::metrics::sweep::CellView;
use omc_fl::runtime::engine::Engine;
use omc_fl::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::new("fig3", "Fig. 3: with vs without PVT, from scratch");
    args.flag("rounds", "federated rounds", Some("100"));
    args.flag("seed", "sweep seed", Some("42"));
    args.flag(
        "format",
        "storage format (paper: S1E5M10 at 12K rounds; coarser here to \
         surface the effect at small scale)",
        Some("S1E3M4"),
    );
    args.flag("model-dir", "artifact dir (or native:tiny)", Some("artifacts/small"));
    let m = args.parse();
    let scale = Scale::from_flags(m.get_usize("rounds")?, m.get_u64("seed")?);
    let fmt = m.get("format").unwrap();
    let spec = presets::fig3_grid(m.get("model-dir").unwrap(), &scale, fmt)?;

    let engine = Engine::cpu()?;
    let report = sweep::run_sweep(&engine, &spec, &SweepOptions::default())?;

    let with = CellView(&report.cells[0].cell_json);
    let without = CellView(&report.cells[1].cell_json);
    println!("\n## Figure 3 — WER vs round, from scratch at {fmt}\n");
    println!("{:>6} {:>14} {:>14}", "round", "with PVT", "without PVT");
    let without_curve = without.eval_wer_curve();
    for (i, (round, wer_with)) in with.eval_wer_curve().iter().enumerate() {
        if let Some((_, wer_without)) = without_curve.get(i) {
            println!("{round:>6} {wer_with:>13.2}% {wer_without:>13.2}%");
        }
    }
    let (wer_with, wer_without) = (with.final_wer(), without.final_wer());
    println!(
        "\nfinal WER: with PVT {wer_with:.2}% vs without {wer_without:.2}% \
         (paper shape: without-PVT diverges/stalls; with-PVT keeps improving)"
    );
    // divergence check: did the without-PVT curve rise from its best?
    let best_without = without_curve
        .iter()
        .map(|&(_, w)| w)
        .fold(f64::INFINITY, f64::min);
    println!(
        "without-PVT best {best_without:.2}% -> final {wer_without:.2}% \
         (rise = instability signal)"
    );
    println!("curve CSVs: {}/cells/*.csv", spec.output_dir.display());
    Ok(())
}
