//! **Figure 3** — per-variable transformation stabilizes from-scratch
//! training.
//!
//! Paper: training the non-streaming Conformer from scratch at S1E5M10
//! *without* PVT is unstable — WER decreases, then climbs after ~12K
//! rounds; with PVT it keeps decreasing.
//!
//! Scale substitution (documented in DESIGN.md §2/§5): 12K-round horizons
//! are out of reach on this testbed, so the error-accumulation mechanism is
//! surfaced at small scale with a coarser format (default S1E3M4,
//! all-parameter quantization — the regime where the unconditioned
//! quantizer bias actually bites within ~100 rounds). The *comparison*
//! (with-PVT stays stable and strictly better) is the reproduced shape.
//!
//!     cargo run --release --example fig3_pvt_stability -- --rounds 100

use anyhow::Result;
use omc_fl::coordinator::config::OmcConfig;
use omc_fl::coordinator::presets::{self, Scale};
use omc_fl::data::partition::Partition;
use omc_fl::runtime::engine::Engine;
use omc_fl::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::new("fig3", "Fig. 3: with vs without PVT, from scratch");
    args.flag("rounds", "federated rounds", Some("100"));
    args.flag("seed", "rng seed", Some("42"));
    args.flag(
        "format",
        "storage format (paper: S1E5M10 at 12K rounds; coarser here to \
         surface the effect at small scale)",
        Some("S1E3M4"),
    );
    args.flag("model-dir", "artifact dir", Some("artifacts/small"));
    let m = args.parse();
    let scale = Scale::from_flags(m.get_usize("rounds")?, m.get_u64("seed")?);
    let model_dir = m.get("model-dir").unwrap();
    let fmt = m.get("format").unwrap();
    let out = "results/fig3";

    let engine = Engine::cpu()?;
    let model = presets::bind_model(&engine, model_dir)?;

    let mut curves = Vec::new();
    for (label, use_pvt) in [("with_pvt", true), ("without_pvt", false)] {
        let omc = OmcConfig {
            format: fmt.parse()?,
            use_pvt,
            weights_only: false, // quantize everything: the unstable regime
            fraction: 1.0,
        };
        let mut cfg = presets::experiment(
            label, model_dir, &scale, Partition::Iid, 0, omc, out,
        );
        cfg.eval_every = (scale.rounds / 25).max(1); // dense curve
        println!("== from-scratch at {fmt}, {label} ==");
        let (rec, summary) = presets::run_variant(&model, cfg)?;
        curves.push((label, rec, summary));
    }

    println!("\n## Figure 3 — WER vs round, from scratch at {fmt}\n");
    println!("{:>6} {:>14} {:>14}", "round", "with PVT", "without PVT");
    let (with, without) = (&curves[0].1, &curves[1].1);
    for (a, b) in with.records.iter().zip(&without.records) {
        if a.eval_wer >= 0.0 {
            println!("{:>6} {:>13.2}% {:>13.2}%", a.round, a.eval_wer, b.eval_wer);
        }
    }
    let wer_with = curves[0].2.final_wer;
    let wer_without = curves[1].2.final_wer;
    println!(
        "\nfinal WER: with PVT {wer_with:.2}% vs without {wer_without:.2}% \
         (paper shape: without-PVT diverges/stalls; with-PVT keeps improving)"
    );
    // divergence check: did the without-PVT curve rise from its best?
    let best_without = without
        .records
        .iter()
        .filter(|r| r.eval_wer >= 0.0)
        .map(|r| r.eval_wer)
        .fold(f64::INFINITY, f64::min);
    println!(
        "without-PVT best {best_without:.2}% -> final {wer_without:.2}% \
         (rise = instability signal)"
    );
    println!("curve CSVs: {out}/*.csv");
    Ok(())
}
