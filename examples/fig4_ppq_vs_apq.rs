//! **Figure 4** — partial parameter quantization (PPQ, 11-bit S1E3M7 on 90%
//! of weights) vs all-parameter quantization (APQ) with the 13-bit formats
//! S1E3M9 / S1E4M8 / S1E5M7.
//!
//! Paper: PPQ@11bit converges faster AND reaches a lower WER than every
//! APQ@13bit variant, even though the average bitwidth is comparable
//! (90%·11 + 10%·32 ≈ 13.1 bits).
//!
//!     cargo run --release --example fig4_ppq_vs_apq -- --rounds 60

use anyhow::Result;
use omc_fl::coordinator::config::OmcConfig;
use omc_fl::coordinator::experiment::print_table;
use omc_fl::coordinator::presets::{self, Scale};
use omc_fl::data::partition::Partition;
use omc_fl::runtime::engine::Engine;
use omc_fl::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::new("fig4", "Fig. 4: PPQ 11-bit vs APQ 13-bit");
    args.flag("pretrain-rounds", "rounds on the source domain", Some("60"));
    args.flag("rounds", "adaptation rounds per curve", Some("60"));
    args.flag("seed", "rng seed", Some("42"));
    args.flag("model-dir", "artifact dir", Some("artifacts/small_streaming"));
    let m = args.parse();
    let scale = Scale::from_flags(m.get_usize("rounds")?, m.get_u64("seed")?);
    let model_dir = m.get("model-dir").unwrap();
    let out = "results/fig4";
    let ckpt = std::path::PathBuf::from(out).join("pretrained.bin");

    let engine = Engine::cpu()?;
    let model = presets::bind_model(&engine, model_dir)?;

    // shared pretraining (same adaptation setting as Table 2 / Table 4)
    let mut pre_cfg = presets::experiment(
        "pretrain_domain0",
        model_dir,
        &Scale::from_flags(m.get_usize("pretrain-rounds")?, scale.seed),
        Partition::Iid,
        0,
        OmcConfig::fp32_baseline(),
        out,
    );
    pre_cfg.save_to = Some(ckpt.clone());
    println!("== pretraining on source domain (FP32) ==");
    presets::run_variant(&model, pre_cfg)?;

    // PPQ: 90% of weights at 11 bits. APQ: 100% of weights at 13 bits.
    let variants: Vec<(String, OmcConfig)> = vec![
        (
            "PPQ S1E3M7 @ 90%".into(),
            OmcConfig {
                format: "S1E3M7".parse()?,
                use_pvt: true,
                weights_only: true,
                fraction: 0.9,
            },
        ),
        ("APQ S1E3M9 @ 100%".into(), apq("S1E3M9")?),
        ("APQ S1E4M8 @ 100%".into(), apq("S1E4M8")?),
        ("APQ S1E5M7 @ 100%".into(), apq("S1E5M7")?),
    ];

    let mut rows = Vec::new();
    let mut curves = Vec::new();
    for (label, omc) in variants {
        let mut cfg = presets::experiment(
            &label, model_dir, &scale, Partition::Iid, 1, omc, out,
        );
        cfg.init_from = Some(ckpt.clone());
        cfg.lr = 0.05;
        cfg.eval_every = (scale.rounds / 15).max(1);
        println!("== adaptation curve: {label} ==");
        let (rec, summary) = presets::run_variant(&model, cfg)?;
        curves.push((label.clone(), rec));
        rows.push(summary);
    }

    println!("\n## Figure 4 — WER vs round (adaptation)\n");
    print!("{:>6}", "round");
    for (label, _) in &curves {
        print!(" {:>19}", label);
    }
    println!();
    let nrec = curves[0].1.records.len();
    for i in 0..nrec {
        if curves[0].1.records[i].eval_wer < 0.0 {
            continue;
        }
        print!("{:>6}", curves[0].1.records[i].round);
        for (_, rec) in &curves {
            print!(" {:>18.2}%", rec.records[i].eval_wer);
        }
        println!();
    }

    print_table("Figure 4 — final WERs", &rows);
    let ppq = rows[0].final_wer;
    let best_apq = rows[1..]
        .iter()
        .map(|r| r.final_wer)
        .fold(f64::INFINITY, f64::min);
    println!(
        "shape check: PPQ {ppq:.2}% vs best APQ {best_apq:.2}% \
         (paper: PPQ wins every APQ-13bit variant)"
    );
    println!("curve CSVs: {out}/*.csv");
    Ok(())
}

fn apq(fmt: &str) -> Result<OmcConfig> {
    Ok(OmcConfig {
        format: fmt.parse()?,
        use_pvt: true,
        weights_only: true,
        fraction: 1.0,
    })
}
