//! **Figure 4** — partial parameter quantization (PPQ, 11-bit S1E3M7 on 90%
//! of weights) vs all-parameter quantization (APQ) with the 13-bit formats
//! S1E3M9 / S1E4M8 / S1E5M7.
//!
//! Paper: PPQ@11bit converges faster AND reaches a lower WER than every
//! APQ@13bit variant, even though the average bitwidth is comparable
//! (90%·11 + 10%·32 ≈ 13.1 bits).
//!
//! Thin wrapper over `presets::fig4_grid` — identical to
//! `omc-fl sweep --preset fig4`. Curves print from the cells'
//! deterministic `eval_wer_curve` summaries.
//!
//!     cargo run --release --example fig4_ppq_vs_apq -- --rounds 60

use anyhow::Result;
use omc_fl::coordinator::presets::{self, Scale};
use omc_fl::coordinator::sweep::{self, SweepOptions};
use omc_fl::metrics::sweep::CellView;
use omc_fl::runtime::engine::Engine;
use omc_fl::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::new("fig4", "Fig. 4: PPQ 11-bit vs APQ 13-bit");
    args.flag("pretrain-rounds", "rounds on the source domain", Some("60"));
    args.flag("rounds", "adaptation rounds per curve", Some("60"));
    args.flag("seed", "sweep seed", Some("42"));
    args.flag(
        "model-dir",
        "artifact dir (or native:tiny)",
        Some("artifacts/small_streaming"),
    );
    let m = args.parse();
    let scale = Scale::from_flags(m.get_usize("rounds")?, m.get_u64("seed")?);
    let spec = presets::fig4_grid(
        m.get("model-dir").unwrap(),
        &scale,
        m.get_usize("pretrain-rounds")?,
    )?;

    let engine = Engine::cpu()?;
    let report = sweep::run_sweep(&engine, &spec, &SweepOptions::default())?;

    let cells: Vec<CellView<'_>> = report
        .cells
        .iter()
        .map(|o| CellView(&o.cell_json))
        .collect();
    println!("\n## Figure 4 — WER vs round (adaptation)\n");
    print!("{:>6}", "round");
    for c in &cells {
        print!(" {:>19}", c.label());
    }
    println!();
    let curves: Vec<Vec<(usize, f64)>> =
        cells.iter().map(|c| c.eval_wer_curve()).collect();
    for (i, &(round, _)) in curves[0].iter().enumerate() {
        print!("{round:>6}");
        for curve in &curves {
            match curve.get(i) {
                Some(&(_, wer)) => print!(" {wer:>18.2}%"),
                None => print!(" {:>19}", "-"),
            }
        }
        println!();
    }

    sweep::print_report("Figure 4 — final WERs", &report);
    let ppq = cells[0].final_wer();
    let best_apq = cells[1..]
        .iter()
        .map(|c| c.final_wer())
        .fold(f64::INFINITY, f64::min);
    println!(
        "shape check: PPQ {ppq:.2}% vs best APQ {best_apq:.2}% \
         (paper: PPQ wins every APQ-13bit variant)"
    );
    println!("curve CSVs: {}/cells/*.csv", spec.output_dir.display());
    Ok(())
}
