//! **Table 1** — Non-streaming Conformer on IID LibriSpeech, from scratch.
//!
//! Paper rows: FP32 (S1E8M23) vs OMC (S1E4M14): comparable WER at 64%
//! parameter memory/communication and 91% speed.
//!
//! Thin wrapper over the `presets::table1_grid` sweep — identical to
//! `omc-fl sweep --preset table1`. Cell seeds derive from
//! `(seed, cell index)`; per-cell logs and deterministic summaries land
//! under `results/table1/cells/`.
//!
//!     cargo run --release --example table1_iid_fromscratch -- --rounds 80
//!
//! Runs against the PJRT artifacts by default; pass
//! `--model-dir native:tiny` to exercise it anywhere.

use anyhow::Result;
use omc_fl::coordinator::presets::{self, Scale};
use omc_fl::coordinator::sweep::{self, SweepOptions};
use omc_fl::metrics::sweep::CellView;
use omc_fl::runtime::engine::Engine;
use omc_fl::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::new("table1", "Table 1: FP32 vs OMC S1E4M14, IID, from scratch");
    args.flag("rounds", "federated rounds", Some("80"));
    args.flag("seed", "sweep seed", Some("42"));
    args.flag("model-dir", "artifact dir (or native:tiny)", Some("artifacts/small"));
    let m = args.parse();
    let scale = Scale::from_flags(m.get_usize("rounds")?, m.get_u64("seed")?);
    let spec = presets::table1_grid(m.get("model-dir").unwrap(), &scale)?;

    let engine = Engine::cpu()?;
    let report = sweep::run_sweep(&engine, &spec, &SweepOptions::default())?;
    sweep::print_report(
        "Table 1 — non-streaming conformer-lite on IID synthetic ASR (from scratch)",
        &report,
    );
    let wer = |i: usize| CellView(&report.cells[i].cell_json).final_wer();
    let ratio = CellView(&report.cells[1].cell_json).memory_ratio();
    println!(
        "WER gap |OMC - FP32| = {:.2} points (paper: ~0); \
         memory ratio {:.0}% (paper: 64%)",
        (wer(1) - wer(0)).abs(),
        100.0 * ratio
    );
    println!("per-cell logs: {}/cells/*.csv", spec.output_dir.display());
    Ok(())
}
