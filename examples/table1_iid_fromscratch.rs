//! **Table 1** — Non-streaming Conformer on IID LibriSpeech, from scratch.
//!
//! Paper rows: FP32 (S1E8M23) vs OMC (S1E4M14): comparable WER at 64%
//! parameter memory/communication and 91% speed.
//!
//! Here: conformer-lite (`artifacts/small`, non-streaming) on the IID
//! synthetic ASR task, trained from scratch. The shape to reproduce:
//! WER(OMC) ≈ WER(FP32), memory/comm ratio ≈ 0.9·19/32 + 0.1 per weight
//! byte, speed within a modest overhead.
//!
//!     cargo run --release --example table1_iid_fromscratch -- --rounds 80

use anyhow::Result;
use omc_fl::coordinator::config::OmcConfig;
use omc_fl::coordinator::experiment::print_table;
use omc_fl::coordinator::presets::{self, Scale};
use omc_fl::data::partition::Partition;
use omc_fl::runtime::engine::Engine;
use omc_fl::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::new("table1", "Table 1: FP32 vs OMC S1E4M14, IID, from scratch");
    args.flag("rounds", "federated rounds", Some("80"));
    args.flag("seed", "rng seed", Some("42"));
    args.flag("model-dir", "artifact dir", Some("artifacts/small"));
    let m = args.parse();
    let scale = Scale::from_flags(m.get_usize("rounds")?, m.get_u64("seed")?);
    let model_dir = m.get("model-dir").unwrap();
    let out = "results/table1";

    let engine = Engine::cpu()?;
    let model = presets::bind_model(&engine, model_dir)?;

    let variants = [
        ("FP32 (S1E8M23)", OmcConfig::fp32_baseline()),
        ("OMC (S1E4M14)", OmcConfig::paper("S1E4M14".parse()?)),
    ];
    let mut rows = Vec::new();
    for (label, omc) in variants {
        let cfg = presets::experiment(
            label, model_dir, &scale, Partition::Iid, 0, omc, out,
        );
        let (_, summary) = presets::run_variant(&model, cfg)?;
        rows.push(summary);
    }

    print_table(
        "Table 1 — non-streaming conformer-lite on IID synthetic ASR (from scratch)",
        &rows,
    );
    let wer_gap = (rows[1].final_wer - rows[0].final_wer).abs();
    println!(
        "WER gap |OMC - FP32| = {wer_gap:.2} points (paper: ~0); \
         memory ratio {:.0}% (paper: 64%)",
        100.0 * rows[1].memory_ratio
    );
    println!("per-round logs: {out}/*.csv");
    Ok(())
}
