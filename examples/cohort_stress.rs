//! **Cohort stress** — OMC under realistic cross-device cohort failures.
//!
//! The tables assume an ideal cohort: every sampled client trains and
//! reports in time. Production cross-device FL does not look like that —
//! devices drop mid-round, stragglers miss the reporting deadline, and
//! clients hold different amounts of data. This driver runs the paper's
//! OMC configuration through the `presets::cohort_ladder` failure
//! scenarios and reports, per scenario: final WER, mean completion rate,
//! per-round transport (including the uplink bytes *wasted* on
//! past-deadline clients), and speed.
//!
//! The loss/WER trajectory degrades gracefully with completion rate —
//! aggregation weights renormalize over the completing subset each round —
//! while the byte accounting makes the cost of stragglers visible.
//!
//!     cargo run --release --example cohort_stress -- --rounds 60

use anyhow::Result;
use omc_fl::coordinator::config::OmcConfig;
use omc_fl::coordinator::experiment::human_bytes;
use omc_fl::coordinator::presets::{self, Scale};
use omc_fl::data::partition::Partition;
use omc_fl::runtime::engine::Engine;
use omc_fl::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::new(
        "cohort_stress",
        "OMC rounds under dropout / straggler / weighted-FedAvg cohorts",
    );
    args.flag("rounds", "federated rounds per scenario", Some("60"));
    args.flag("seed", "rng seed", Some("42"));
    args.flag("model-dir", "artifact dir", Some("artifacts/small"));
    args.flag("format", "OMC storage format", Some("S1E4M14"));
    let m = args.parse();
    let scale = Scale::from_flags(m.get_usize("rounds")?, m.get_u64("seed")?);
    let model_dir = m.get("model-dir").unwrap();
    let omc = OmcConfig::paper(m.get("format").unwrap().parse()?);
    let out = "results/cohort_stress";

    let engine = Engine::cpu()?;
    let model = presets::bind_model(&engine, model_dir)?;

    println!(
        "\n## Cohort stress — OMC {} under failure scenarios\n",
        m.get("format").unwrap()
    );
    println!(
        "| {:<36} | {:>7} | {:>10} | {:>14} | {:>12} | {:>10} |",
        "", "WER", "completion", "comm/round", "wasted up", "rounds/min"
    );
    println!(
        "|{}|{}|{}|{}|{}|{}|",
        "-".repeat(38),
        "-".repeat(9),
        "-".repeat(12),
        "-".repeat(16),
        "-".repeat(14),
        "-".repeat(12)
    );

    for (label, cohort) in presets::cohort_ladder() {
        let mut cfg = presets::experiment(
            &label,
            model_dir,
            &scale,
            // by-speaker shards give clients different example counts, so
            // the weighted-FedAvg rung actually reweights something
            Partition::BySpeaker,
            0,
            omc,
            out,
        );
        cfg.cohort = cohort;
        let (rec, summary) = presets::run_variant(&model, cfg)?;
        let rounds = rec.records.len().max(1) as f64;
        let wasted: usize =
            rec.records.iter().map(|r| r.up_bytes_discarded).sum();
        println!(
            "| {:<36} | {:>6.2}% | {:>9.0}% | {:>14} | {:>12} | {:>10.1} |",
            label,
            summary.final_wer,
            100.0 * rec.mean_completion_rate(),
            human_bytes((summary.comm_bytes_per_round) as usize),
            human_bytes((wasted as f64 / rounds) as usize),
            summary.rounds_per_min,
        );
    }
    println!("\nper-round logs (incl. sampled/completed/dropped/late columns): {out}/*.csv");
    Ok(())
}
