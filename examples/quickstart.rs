//! Quickstart: train a conformer-lite with federated learning twice — once
//! in FP32, once with OMC at the paper's S1E4M14 format — and compare WER,
//! parameter memory, communication, and speed.
//!
//!     python python/compile/aot.py --out-dir artifacts
//!     cargo run --release --example quickstart -- --rounds 30
//!
//! This is deliberately the whole public-API surface in ~60 lines: engine,
//! experiment config, run, summary.

use anyhow::Result;
use omc_fl::coordinator::config::{ExperimentConfig, OmcConfig};
use omc_fl::coordinator::experiment::{print_table, Experiment};
use omc_fl::runtime::engine::Engine;
use omc_fl::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::new("quickstart", "FP32 vs OMC on the small model");
    args.flag("rounds", "federated rounds per run", Some("30"));
    args.flag("model-dir", "artifact directory", Some("artifacts/small"));
    args.flag("format", "OMC storage format", Some("S1E4M14"));
    let m = args.parse();
    let rounds = m.get_usize("rounds")?;
    let model_dir = std::path::PathBuf::from(m.get("model-dir").unwrap());

    let engine = Engine::cpu()?;
    let mut rows = Vec::new();

    for (label, omc) in [
        ("FP32 (S1E8M23)".to_string(), OmcConfig::fp32_baseline()),
        (
            format!("OMC ({})", m.get("format").unwrap()),
            OmcConfig::paper(m.get("format").unwrap().parse()?),
        ),
    ] {
        let mut cfg = ExperimentConfig::default_with(&label, &model_dir);
        cfg.rounds = rounds;
        cfg.num_clients = 32;
        cfg.clients_per_round = 8;
        cfg.eval_every = (rounds / 4).max(1);
        cfg.omc = omc;
        cfg.output_dir = "results/quickstart".into();
        let mut exp = Experiment::prepare(&engine, cfg)?;
        let (rec, summary) = exp.run()?;
        rec.write(std::path::Path::new("results/quickstart"))?;
        rows.push(summary);
    }

    print_table("Quickstart: conformer-lite on the synthetic ASR task", &rows);
    println!("per-round logs: results/quickstart/*.csv");
    Ok(())
}
