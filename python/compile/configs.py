"""Model/data size presets shared by the AOT pipeline and the manifest.

Each preset fully determines the lowered HLO shapes (batch and sequence
lengths are static under AOT), so the Rust coordinator reads these back from
``artifacts/<size>/manifest.json`` instead of duplicating them.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    """conformer-lite hyper-parameters.

    The variable taxonomy (weight matrices vs. norm scales/biases) mirrors the
    paper's Sec. 2.4 distinction; ``streaming`` selects causal attention and a
    causally-padded depthwise convolution (the paper's streaming Conformer).
    """

    name: str = "tiny"
    feature_dim: int = 16       # F: input "acoustic" feature size
    vocab: int = 32             # V: output token vocabulary
    d_model: int = 32           # d
    ff_mult: int = 4            # FFN hidden = ff_mult * d
    num_heads: int = 2
    num_blocks: int = 1
    conv_kernel: int = 5        # depthwise conv width (odd)
    gn_groups: int = 4          # GroupNorm groups in the conv module
    streaming: bool = False
    batch: int = 4              # B (static in the lowered artifact)
    seq_len: int = 16           # T (static in the lowered artifact)

    def ff_dim(self) -> int:
        return self.ff_mult * self.d_model

    def head_dim(self) -> int:
        assert self.d_model % self.num_heads == 0
        return self.d_model // self.num_heads

    def to_dict(self) -> dict:
        return asdict(self)


# Size ladder. `tiny` keeps the pytest + cargo-test cycle fast; `small` drives
# the paper-table examples; `base` is the non-streaming analog; `large` is the
# end-to-end validation model (EXPERIMENTS.md §E2E).
PRESETS: dict[str, ModelConfig] = {
    "tiny": ModelConfig(
        name="tiny", feature_dim=16, vocab=32, d_model=32, num_heads=2,
        num_blocks=1, batch=4, seq_len=16, streaming=False,
    ),
    "small": ModelConfig(
        name="small", feature_dim=24, vocab=48, d_model=64, num_heads=4,
        num_blocks=2, batch=8, seq_len=24, streaming=False,
    ),
    # streaming variant used by the Table-2/Table-4 adaptation experiments
    "small_streaming": ModelConfig(
        name="small_streaming", feature_dim=24, vocab=48, d_model=64,
        num_heads=4, num_blocks=2, batch=8, seq_len=24, streaming=True,
    ),
    "base": ModelConfig(
        name="base", feature_dim=32, vocab=64, d_model=128, num_heads=4,
        num_blocks=4, batch=8, seq_len=32, streaming=False,
    ),
    "large": ModelConfig(
        name="large", feature_dim=48, vocab=96, d_model=256, num_heads=8,
        num_blocks=6, batch=4, seq_len=32, streaming=True,
    ),
}

DEFAULT_SIZES = ("tiny", "small", "small_streaming")
