"""L2 — conformer-lite in functional JAX.

A size-configurable stand-in for the paper's Conformer ASR models (DESIGN.md
§2): macaron feed-forward halves, multi-head self-attention (causal when
``streaming``), a depthwise-convolution module with GroupNorm (the paper's
BatchNorm→GroupNorm substitution for FL), LayerNorms, framewise CE loss and
greedy decoding.

Parameters are an *ordered flat list* — the lowered HLO takes one operand per
variable, and ``specs()`` is serialized into ``manifest.json`` so the Rust
coordinator binds operands by position. Variable ``kind`` drives the paper's
weight-matrices-only rule: only ``kind == "weight"`` is eligible for
quantization (Sec. 2.4).
"""

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import omc
from .configs import ModelConfig

F32 = jnp.float32


@dataclass(frozen=True)
class VarSpec:
    name: str
    shape: tuple
    kind: str  # "weight" | "bias" | "norm_scale" | "norm_bias"

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))


def specs(cfg: ModelConfig) -> list:
    """The ordered variable table for a model configuration."""
    d = cfg.d_model
    ff = cfg.ff_dim()
    out = [
        VarSpec("input_proj/w", (cfg.feature_dim, d), "weight"),
        VarSpec("input_proj/b", (d,), "bias"),
    ]
    def ffn_specs(p, half):
        return [
            VarSpec(f"{p}/{half}/ln_scale", (d,), "norm_scale"),
            VarSpec(f"{p}/{half}/ln_bias", (d,), "norm_bias"),
            VarSpec(f"{p}/{half}/w1", (d, ff), "weight"),
            VarSpec(f"{p}/{half}/b1", (ff,), "bias"),
            VarSpec(f"{p}/{half}/w2", (ff, d), "weight"),
            VarSpec(f"{p}/{half}/b2", (d,), "bias"),
        ]

    for i in range(cfg.num_blocks):
        p = f"block{i}"
        out += ffn_specs(p, "ffn1")
        out += [
            VarSpec(f"{p}/mhsa/ln_scale", (d,), "norm_scale"),
            VarSpec(f"{p}/mhsa/ln_bias", (d,), "norm_bias"),
            VarSpec(f"{p}/mhsa/wq", (d, d), "weight"),
            VarSpec(f"{p}/mhsa/bq", (d,), "bias"),
            VarSpec(f"{p}/mhsa/wk", (d, d), "weight"),
            VarSpec(f"{p}/mhsa/bk", (d,), "bias"),
            VarSpec(f"{p}/mhsa/wv", (d, d), "weight"),
            VarSpec(f"{p}/mhsa/bv", (d,), "bias"),
            VarSpec(f"{p}/mhsa/wo", (d, d), "weight"),
            VarSpec(f"{p}/mhsa/bo", (d,), "bias"),
            VarSpec(f"{p}/conv/ln_scale", (d,), "norm_scale"),
            VarSpec(f"{p}/conv/ln_bias", (d,), "norm_bias"),
            VarSpec(f"{p}/conv/pw1", (d, 2 * d), "weight"),
            VarSpec(f"{p}/conv/pw1_b", (2 * d,), "bias"),
            VarSpec(f"{p}/conv/dw", (cfg.conv_kernel, d), "weight"),
            VarSpec(f"{p}/conv/dw_b", (d,), "bias"),
            VarSpec(f"{p}/conv/gn_scale", (d,), "norm_scale"),
            VarSpec(f"{p}/conv/gn_bias", (d,), "norm_bias"),
            VarSpec(f"{p}/conv/pw2", (d, d), "weight"),
            VarSpec(f"{p}/conv/pw2_b", (d,), "bias"),
        ]
        out += ffn_specs(p, "ffn2")
        out += [
            VarSpec(f"{p}/final_ln_scale", (d,), "norm_scale"),
            VarSpec(f"{p}/final_ln_bias", (d,), "norm_bias"),
        ]
    out += [
        VarSpec("output_proj/w", (d, cfg.vocab), "weight"),
        VarSpec("output_proj/b", (cfg.vocab,), "bias"),
    ]
    return out


def init_params(cfg: ModelConfig, key) -> list:
    """Xavier-uniform weights, zero biases, unit norm scales."""
    params = []
    for spec in specs(cfg):
        key, sub = jax.random.split(key)
        if spec.kind == "weight":
            if len(spec.shape) == 2:
                fan_in, fan_out = spec.shape
            else:  # depthwise conv (k, d): per-channel fan-in = k
                fan_in, fan_out = spec.shape[0], spec.shape[0]
            limit = math.sqrt(6.0 / (fan_in + fan_out))
            params.append(jax.random.uniform(
                sub, spec.shape, F32, -limit, limit))
        elif spec.kind == "norm_scale":
            params.append(jnp.ones(spec.shape, F32))
        else:
            params.append(jnp.zeros(spec.shape, F32))
    return params


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def _layernorm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _groupnorm(x, scale, bias, groups, eps=1e-5):
    b, t, d = x.shape
    g = x.reshape(b, t, groups, d // groups)
    mu = jnp.mean(g, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(g - mu), axis=-1, keepdims=True)
    g = (g - mu) * jax.lax.rsqrt(var + eps)
    return g.reshape(b, t, d) * scale + bias


def _swish(x):
    return x * jax.nn.sigmoid(x)


def _mhsa(x, wq, bq, wk, bk, wv, bv, wo, bo, heads, causal):
    b, t, d = x.shape
    dh = d // heads
    q = (x @ wq + bq).reshape(b, t, heads, dh).transpose(0, 2, 1, 3)
    k = (x @ wk + bk).reshape(b, t, heads, dh).transpose(0, 2, 1, 3)
    v = (x @ wv + bv).reshape(b, t, heads, dh).transpose(0, 2, 1, 3)
    logits = (q @ k.transpose(0, 1, 3, 2)) / math.sqrt(dh)
    if causal:
        mask = jnp.tril(jnp.ones((t, t), bool))
        logits = jnp.where(mask, logits, -1e9)
    attn = jax.nn.softmax(logits, axis=-1)
    y = (attn @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    return y @ wo + bo


def _depthwise_conv(x, w, causal):
    """x: [B,T,d], w: [k,d] depthwise kernel."""
    k, d = w.shape
    pad = [(k - 1, 0)] if causal else [((k - 1) // 2, k // 2)]
    return jax.lax.conv_general_dilated(
        x, w.reshape(k, 1, d),
        window_strides=(1,), padding=pad,
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=d)


def forward(cfg: ModelConfig, params: list, x):
    """x: [B,T,F] f32 → logits [B,T,V]."""
    it = iter(params)
    nxt = lambda: next(it)
    h = x @ nxt() + nxt()

    def ffn(h):
        # consumes ln_scale, ln_bias, w1, b1, w2, b2 — matches ffn_specs()
        y = _layernorm(h, nxt(), nxt())
        y = _swish(y @ nxt() + nxt())
        return y @ nxt() + nxt()

    for _ in range(cfg.num_blocks):
        # FFN half 1 (macaron)
        h = h + 0.5 * ffn(h)
        # MHSA
        y = _layernorm(h, nxt(), nxt())
        y = _mhsa(y, nxt(), nxt(), nxt(), nxt(), nxt(), nxt(), nxt(), nxt(),
                  cfg.num_heads, cfg.streaming)
        h = h + y
        # Conv module
        y = _layernorm(h, nxt(), nxt())
        y = y @ nxt() + nxt()           # pointwise 1 → [B,T,2d]
        a, g = jnp.split(y, 2, axis=-1)
        y = a * jax.nn.sigmoid(g)       # GLU
        y = _depthwise_conv(y, nxt(), cfg.streaming) + nxt()
        y = _groupnorm(y, nxt(), nxt(), cfg.gn_groups)
        y = _swish(y)
        y = y @ nxt() + nxt()           # pointwise 2
        h = h + y
        # FFN half 2 (macaron)
        h = h + 0.5 * ffn(h)
        # final block LayerNorm
        h = _layernorm(h, nxt(), nxt())
    logits = h @ nxt() + nxt()
    return logits


def loss_fn(cfg: ModelConfig, params: list, x, y):
    """Framewise cross-entropy, mean over batch and time."""
    logits = forward(cfg, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# lowered entry points (aot.py lowers each of these once per model size)
# ---------------------------------------------------------------------------

def make_init_fn(cfg: ModelConfig):
    def init(seed):
        key = jax.random.PRNGKey(seed.astype(jnp.uint32))
        return tuple(init_params(cfg, key))
    return init


def make_train_fp32_fn(cfg: ModelConfig):
    """(V_1..V_n, x, y, lr) → (V'_1..V'_n, loss) — plain SGD client step."""
    n = len(specs(cfg))

    def train(*args):
        params = list(args[:n])
        x, y, lr = args[n], args[n + 1], args[n + 2]
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, x, y))(params)
        new = [p - lr * g for p, g in zip(params, grads)]
        return tuple(new) + (loss,)

    return train


def make_train_omc_fn(cfg: ModelConfig, use_pvt: bool = True):
    """OMC client step (DESIGN.md §6).

    (Ṽ_1..Ṽ_n, s[n], b[n], mask[n], x, y, lr, e, m)
        → (Ṽ'_1..Ṽ'_n, s'[n], b'[n], loss)

    Decompress → fwd/bwd → SGD → masked re-compress (quantize via the Pallas
    kernel + PVT fit). ``use_pvt=False`` lowers the Table-4 "quantization
    only" ablation artifact.
    """
    n = len(specs(cfg))

    def train(*args):
        tildes = list(args[:n])
        s, b, mask = args[n], args[n + 1], args[n + 2]
        x, y, lr = args[n + 3], args[n + 4], args[n + 5]
        e, m = args[n + 6], args[n + 7]
        params = [omc.decompress(t, s[i], b[i]) for i, t in enumerate(tildes)]
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, x, y))(params)
        new_t, new_s, new_b = [], [], []
        for i, (p, g) in enumerate(zip(params, grads)):
            v = p - lr * g
            vt, s_i, b_i = omc.compress_masked(v, mask[i], e, m, use_pvt)
            new_t.append(vt)
            new_s.append(s_i)
            new_b.append(b_i)
        return (tuple(new_t)
                + (jnp.stack(new_s), jnp.stack(new_b), loss))

    return train


def make_eval_fn(cfg: ModelConfig):
    """(V_1..V_n, x, y) → (loss, pred[B,T] i32) — greedy framewise decode."""
    n = len(specs(cfg))

    def evaluate(*args):
        params = list(args[:n])
        x, y = args[n], args[n + 1]
        logits = forward(cfg, params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jnp.mean(nll), pred

    return evaluate


def make_quant_fn():
    """(v[N], e, m) → ṽ[N] — standalone quantizer artifact for the
    cross-layer bit-exactness test (Rust codec vs Pallas kernel)."""

    def quantize(v, e, m):
        return (omc.compress(v, e, m)[0],)

    return quantize
