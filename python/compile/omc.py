"""L2 glue — the OMC compress/decompress steps as they appear inside the
lowered training graph.

The graph-side contract (see DESIGN.md §6): the Rust coordinator owns the
bit-packed storage; the graph receives the *decoded* quantized values
``Ṽ`` (every element exactly SxEyMz-representable) plus the per-variable
transform scalars ``(s, b)`` and a 0/1 selection mask, and must return the
same triple for the updated parameters.
"""

import jax.numpy as jnp

from .kernels import quant, ref


def decompress(vt, s, b):
    """``V̄ = s·Ṽ + b`` in f32. Identity when (s, b) = (1, 0)."""
    return s * vt + b


def compress(v, exp_bits, mant_bits):
    """Quantize one updated variable and fit its per-variable transform.

    The quantization runs through the Pallas kernel for weight-matrix-sized
    variables (the hot spot) and the jnp oracle for small ones; the PVT fit
    accumulates in f64 (Sec. 2.3).
    """
    vt = quant.quantize(v, exp_bits, mant_bits)
    s, b = ref.pvt_fit_ref(v, vt)
    return vt, s, b


def compress_masked(v, mask, exp_bits, mant_bits, use_pvt=True):
    """Masked OMC compress for one variable.

    mask = 1: store quantized + PVT scalars. mask = 0 (unselected under PPQ,
    or not a weight matrix): store raw f32 with the identity transform.
    Branchless select — XLA evaluates both sides; the unselected side is the
    cheap one, and the paper's configuration quantizes 90% of the weight
    matrices anyway.
    """
    vt, s, b = compress(v, exp_bits, mant_bits)
    if not use_pvt:
        # Ablation row "quantization only" (Table 4): identity transform.
        s = jnp.float32(1.0)
        b = jnp.float32(0.0)
    one = jnp.float32(1.0)
    zero = jnp.float32(0.0)
    sel = mask > 0.5
    vt_out = jnp.where(sel, vt, v)
    s_out = jnp.where(sel, s, one)
    b_out = jnp.where(sel, b, zero)
    return vt_out, s_out, b_out
