"""L1 — Pallas SxEyMz fake-quantization kernel.

This is the hot spot of OMC: every client training iteration re-quantizes
every selected weight matrix. The kernel is elementwise integer
bit-manipulation, i.e. VPU work on a real TPU; it is bandwidth-bound, so the
BlockSpec is chosen for HBM<->VMEM streaming, not MXU use (see DESIGN.md
§Hardware-Adaptation):

* the flattened variable is reshaped to ``(rows, 128)`` — 128 is the TPU lane
  width — and tiled in ``(BLOCK_ROWS, 128)`` slabs;
* one input slab + one output slab live in VMEM per grid step
  (``2 * BLOCK_ROWS * 128 * 4`` bytes = 256 KiB at the default 256 rows,
  comfortably double-bufferable in 16 MiB VMEM);
* the dynamic format parameters (e, m) ride along as a tiny ``(2,)`` i32
  operand mapped to every grid step (SMEM-resident on TPU).

``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowers the kernel to plain HLO that
both pytest and the Rust runtime can run. Correctness is asserted bit-exactly
against ``ref.quantize_ref`` (pure jnp) in ``python/tests``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default tile: (256, 128) f32 slab = 128 KiB in, 128 KiB out.
BLOCK_ROWS = 256
LANES = 128


def _quant_kernel(em_ref, x_ref, o_ref):
    """Pallas kernel body: quantize one VMEM slab.

    The bit-math is shared verbatim with the jnp oracle (ref.py) — the kernel
    is the *scheduling* of that math, the oracle is its semantics.
    """
    e = em_ref[0]
    m = em_ref[1]
    o_ref[...] = ref.quantize_u32_math(x_ref[...], e, m)


def _pad_rows(n: int, block_rows: int) -> int:
    rows = -(-n // LANES)
    return -(-rows // block_rows) * block_rows


@functools.partial(jax.jit, static_argnames=("block_rows",))
def quantize_pallas(x, exp_bits, mant_bits, *, block_rows: int = BLOCK_ROWS):
    """Quantize an arbitrary-shape f32 array to SxEyMz via the Pallas kernel.

    Args:
      x: f32 array, any shape.
      exp_bits / mant_bits: i32 scalars (may be traced — one artifact serves
        every format).
      block_rows: tile height; exposed for the §Perf sweep.
    Returns:
      f32 array shaped like ``x`` with every element SxEyMz-representable.
    """
    shape = x.shape
    n = x.size
    if n == 0:
        return x
    rows = _pad_rows(n, block_rows)
    flat = jnp.zeros((rows * LANES,), jnp.float32).at[:n].set(
        x.astype(jnp.float32).ravel())
    grid = rows // block_rows
    em = jnp.stack([jnp.asarray(exp_bits, jnp.int32),
                    jnp.asarray(mant_bits, jnp.int32)])
    out = pl.pallas_call(
        _quant_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),            # (e, m) — every step
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(em, flat.reshape(rows, LANES))
    return out.ravel()[:n].reshape(shape)


# Variables smaller than this skip the Pallas machinery: grid/padding overhead
# would dominate, and the paper's hot spot is the weight matrices anyway
# (99.8% of model size). Semantics are identical either way (tested).
PALLAS_MIN_ELEMS = 4096


def quantize(x, exp_bits, mant_bits):
    """Dispatch: Pallas kernel for large variables, jnp oracle for small."""
    if x.size >= PALLAS_MIN_ELEMS:
        return quantize_pallas(x, exp_bits, mant_bits)
    return ref.quantize_u32_math(
        x, jnp.asarray(exp_bits, jnp.int32), jnp.asarray(mant_bits, jnp.int32))
