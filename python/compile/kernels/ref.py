"""Pure-jnp oracles for the OMC compression math.

These are the correctness ground truth for (a) the Pallas kernel in
``quant.py`` (pytest asserts bit-exact agreement) and (b) the Rust codec in
``rust/src/omc/quantize.rs`` (asserted through the ``quant.hlo.txt`` artifact
in a cargo integration test).

Quantization model — SxEyMz floating point (1 sign bit, ``e`` exponent bits,
``m`` mantissa bits), IEEE-like:

* exponent bias ``2^(e-1) - 1``; the all-ones exponent field is reserved
  (inf/NaN), so the maximum finite unbiased exponent is the bias itself;
* round-to-nearest-even on the mantissa, with the natural carry into the
  exponent;
* gradual underflow (subnormals) below the minimum normal exponent;
* saturating overflow to the maximum finite value (standard practice for
  training-time formats; the paper does not specify and training values are
  far from the range limits). Inf/NaN inputs also saturate — documented.

Everything is expressed as u32 bit manipulation on the f32 encoding, which is
exactly mirrorable in Rust, in the Pallas kernel, and in plain jnp.
"""

import jax
import jax.numpy as jnp

_U32 = jnp.uint32


def quantize_u32_math(x, exp_bits, mant_bits):
    """Quantize f32 values to SxEyMz. Works on traced values.

    Args:
      x: f32 array (any shape).
      exp_bits: int32 scalar (traced OK), 1 <= e <= 8.
      mant_bits: int32 scalar (traced OK), 0 <= m <= 23.
    Returns:
      f32 array of the same shape; every element exactly representable in
      SxEyMz.
    """
    e = exp_bits.astype(_U32) if hasattr(exp_bits, "astype") else _U32(exp_bits)
    m = mant_bits.astype(_U32) if hasattr(mant_bits, "astype") else _U32(mant_bits)

    u = jax.lax.bitcast_convert_type(x.astype(jnp.float32), _U32)
    sign = u & _U32(0x8000_0000)
    mag = u & _U32(0x7FFF_FFFF)

    # Unbiased f32 exponent; biased-0 (f32 subnormal) behaves like biased-1
    # (same 2^-126 scale), which makes the shift formula uniform.
    bexp = (mag >> _U32(23)).astype(jnp.int32)
    unb = jnp.maximum(bexp, 1) - 127

    bias_f = (jnp.int32(1) << (e.astype(jnp.int32) - 1)) - 1
    min_normal_unb = 1 - bias_f

    # --- Normal range: drop (23 - m) f32-mantissa bits with RNE. ----------
    # Masking low bits of the raw encoding is exact within a binade and the
    # carry into the exponent on round-up is the correct next-binade value.
    shift = _U32(23) - m
    sm1 = jnp.maximum(shift, _U32(1)) - _U32(1)
    half = _U32(1) << sm1
    lsb = (mag >> shift) & _U32(1)
    rounded = ((mag + half - _U32(1) + lsb) >> shift) << shift
    q_norm = jnp.where(shift == _U32(0), mag, rounded)

    # --- Subnormal range (unb < min_normal_unb): uniform grid of quantum
    # 2^t, t = min_normal_unb - m. The encoding trick does NOT apply across
    # binades, so round in value space with the exact additive trick:
    # (|x| + C) - C with C = 1.5 * 2^(t+23) rounds |x| to a multiple of 2^t
    # under the FPU's own RNE, and the subtraction is exact (Sterbenz).
    # Requires |x| < 2^(t+22), i.e. m <= 22 whenever the subnormal path can
    # trigger; every format with m = 23 also has e = 8 (plain f32), whose
    # subnormals coincide with f32's own, so the path is never taken there.
    t_plus_150 = (min_normal_unb - m.astype(jnp.int32) + 150).astype(_U32)
    c_enc = (t_plus_150 << _U32(23)) | _U32(0x0040_0000)  # 1.5 * 2^(t+23)
    c = jax.lax.bitcast_convert_type(c_enc, jnp.float32)
    absx = jax.lax.bitcast_convert_type(mag, jnp.float32)
    q_sub = jax.lax.bitcast_convert_type((absx + c) - c, _U32)

    q = jnp.where(unb < min_normal_unb, q_sub, q_norm)

    # Saturate to the maximum finite SxEyMz value (also catches inf/NaN and
    # RNE carry past the top binade).
    max_bexp = (bias_f + 127).astype(_U32)
    frac = ((_U32(1) << m) - _U32(1)) << (_U32(23) - m)
    max_mag = (max_bexp << _U32(23)) | frac
    q = jnp.minimum(q, max_mag)

    out = sign | q
    return jax.lax.bitcast_convert_type(out, jnp.float32)


def quantize_ref(x, exp_bits, mant_bits):
    """Reference quantizer (alias kept for test readability)."""
    return quantize_u32_math(x, jnp.int32(exp_bits), jnp.int32(mant_bits))


def pvt_fit_ref(v, vt):
    """Per-variable transformation: least-squares fit of ``s*vt + b ~= v``.

    The paper's Eq. (1) denominator has a typo (mixes V and Ṽ); this is the
    correct closed form. Accumulation in f64 per Sec. 2.3; the returned
    scalars are f32 (also per Sec. 2.3).

    Degenerate case: denominator 0 (vt constant) => s = 1, b = mean(v - vt).
    A non-finite quotient (pathological cancellation) falls back the same way.
    """
    v64 = v.astype(jnp.float64).ravel()
    t64 = vt.astype(jnp.float64).ravel()
    n = jnp.float64(v64.shape[0])
    sum_v = jnp.sum(v64)
    sum_t = jnp.sum(t64)
    sum_tt = jnp.sum(t64 * t64)
    sum_vt = jnp.sum(v64 * t64)
    den = n * sum_tt - sum_t * sum_t
    num = n * sum_vt - sum_v * sum_t
    s_raw = num / den
    bad = (den == 0.0) | ~jnp.isfinite(s_raw)
    s = jnp.where(bad, 1.0, s_raw)
    b = (sum_v - s * sum_t) / n
    return s.astype(jnp.float32), b.astype(jnp.float32)


def fakequant_pvt_ref(v, exp_bits, mant_bits):
    """Full OMC compress step for one variable: quantize + PVT fit.

    Returns ``(vt, s, b)`` — the exactly-representable quantized values and
    the per-variable transform scalars. The decompressed view the next
    iteration consumes is ``s * vt + b`` (computed in f32, matching the wire
    contract where s/b travel as f32).
    """
    vt = quantize_u32_math(v, jnp.int32(exp_bits), jnp.int32(mant_bits))
    s, b = pvt_fit_ref(v, vt)
    return vt, s, b


def decompress_ref(vt, s, b):
    """PVT decompression ``V̄ = s·Ṽ + b`` in f32 (the on-device compute)."""
    return (s.astype(jnp.float32) * vt.astype(jnp.float32)
            + b.astype(jnp.float32))
