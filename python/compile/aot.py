"""AOT lowering — the ONLY place Python runs; never on the request path.

For every model size this emits, under ``artifacts/<size>/``:

  init.hlo.txt              (seed i32) -> (V_1..V_n)
  train_fp32.hlo.txt        (V.., x, y, lr) -> (V'.., loss)
  train_omc.hlo.txt         (Ṽ.., s[n], b[n], mask[n], x, y, lr, e, m)
                              -> (Ṽ'.., s'[n], b'[n], loss)
  train_omc_nopvt.hlo.txt   same, with the per-variable transform disabled
                              (Table-4 "quantization only" row, Fig. 3)
  eval.hlo.txt              (V.., x, y) -> (loss, pred[B,T] i32)
  manifest.json             variable table + static shapes for the Rust side

plus a size-independent ``artifacts/quant.hlo.txt`` — the standalone Pallas
quantizer, used by a cargo integration test to assert the Rust codec is
bit-identical to the kernel.

Interchange is HLO **text**: the image's xla_extension 0.5.1 rejects
jax>=0.5 serialized HloModuleProto (64-bit instruction ids); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)  # PVT accumulates in f64 (Sec. 2.3)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model as M  # noqa: E402
from .configs import DEFAULT_SIZES, PRESETS  # noqa: E402

QUANT_TEST_N = 8192  # length of the standalone quantizer artifact


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the text
    parser on the Rust side; `return_tuple=True` so outputs are one tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_size(size: str, out_root: str) -> dict:
    cfg = PRESETS[size]
    specs = M.specs(cfg)
    n = len(specs)
    out_dir = os.path.join(out_root, size)
    os.makedirs(out_dir, exist_ok=True)

    param_sds = [_sds(s.shape, jnp.float32) for s in specs]
    x_sds = _sds((cfg.batch, cfg.seq_len, cfg.feature_dim), jnp.float32)
    y_sds = _sds((cfg.batch, cfg.seq_len), jnp.int32)
    scalar_f32 = _sds((), jnp.float32)
    scalar_i32 = _sds((), jnp.int32)
    vecn = _sds((n,), jnp.float32)

    emitted = {}

    def emit(name, fn, *args):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        emitted[name] = f"{name}.hlo.txt"
        print(f"  {size}/{name}: {len(text)} chars")

    emit("init", M.make_init_fn(cfg), scalar_i32)
    emit("train_fp32", M.make_train_fp32_fn(cfg),
         *param_sds, x_sds, y_sds, scalar_f32)
    emit("train_omc", M.make_train_omc_fn(cfg, use_pvt=True),
         *param_sds, vecn, vecn, vecn, x_sds, y_sds,
         scalar_f32, scalar_i32, scalar_i32)
    emit("train_omc_nopvt", M.make_train_omc_fn(cfg, use_pvt=False),
         *param_sds, vecn, vecn, vecn, x_sds, y_sds,
         scalar_f32, scalar_i32, scalar_i32)
    emit("eval", M.make_eval_fn(cfg), *param_sds, x_sds, y_sds)

    manifest = {
        "config": cfg.to_dict(),
        "num_variables": n,
        "total_params": sum(s.size for s in specs),
        "variables": [
            {"name": s.name, "shape": list(s.shape), "kind": s.kind,
             "size": s.size}
            for s in specs
        ],
        "artifacts": emitted,
        "interchange": "hlo-text",
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def lower_quant_artifact(out_root: str):
    os.makedirs(out_root, exist_ok=True)
    fn = M.make_quant_fn()
    lowered = jax.jit(fn).lower(
        _sds((QUANT_TEST_N,), jnp.float32), _sds((), jnp.int32),
        _sds((), jnp.int32))
    text = to_hlo_text(lowered)
    path = os.path.join(out_root, "quant.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"  quant: {len(text)} chars (N={QUANT_TEST_N})")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default=",".join(DEFAULT_SIZES),
                    help=f"comma-separated subset of {sorted(PRESETS)}")
    args = ap.parse_args()
    sizes = [s for s in args.sizes.split(",") if s]
    for s in sizes:
        if s not in PRESETS:
            raise SystemExit(f"unknown size {s!r}; have {sorted(PRESETS)}")
    print(f"AOT lowering sizes={sizes} -> {args.out_dir}")
    lower_quant_artifact(args.out_dir)
    for s in sizes:
        man = lower_size(s, args.out_dir)
        print(f"  {s}: {man['num_variables']} vars, "
              f"{man['total_params']:,} params")
    print("AOT done.")


if __name__ == "__main__":
    main()
