"""L1 correctness: the Pallas SxEyMz quantizer vs the pure-jnp oracle.

The oracle itself is validated against independent ground truths:
IEEE binary16 (== S1E5M10) via numpy, and exhaustive structural properties
(idempotence, monotonicity, symmetry, grid membership). The Pallas kernel
must agree with the oracle *bit-exactly* on every shape/format hypothesis
throws at it — this is the contract the Rust codec also tests against
(through ``artifacts/quant.hlo.txt``).
"""

import numpy as np
import pytest
import jax.numpy as jnp
# hypothesis is absent from the offline image; skip (not error) the
# property tests there so the rest of the suite still runs
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, quant

PAPER_FORMATS = [(8, 23), (5, 10), (4, 14), (3, 7), (2, 3),
                 (3, 9), (4, 8), (5, 7)]


def q_ref(x, e, m):
    return np.asarray(ref.quantize_ref(jnp.asarray(x), e, m))


# ---------------------------------------------------------------------------
# oracle structural properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("e,m", PAPER_FORMATS)
def test_idempotent(e, m):
    rng = np.random.default_rng(42)
    for scale in (1e-4, 0.05, 1.0, 300.0):
        x = (rng.standard_normal(4096) * scale).astype(np.float32)
        q1 = q_ref(x, e, m)
        q2 = q_ref(q1, e, m)
        np.testing.assert_array_equal(q1.view(np.uint32), q2.view(np.uint32))


@pytest.mark.parametrize("e,m", PAPER_FORMATS)
def test_monotone(e, m):
    rng = np.random.default_rng(7)
    x = np.sort((rng.standard_normal(8192) * 2.0).astype(np.float32))
    q = q_ref(x, e, m)
    assert np.all(np.diff(q) >= 0)


@pytest.mark.parametrize("e,m", PAPER_FORMATS)
def test_sign_symmetry(e, m):
    rng = np.random.default_rng(3)
    x = (rng.standard_normal(2048) * 0.1).astype(np.float32)
    a = q_ref(x, e, m)
    b = q_ref(-x, e, m)
    np.testing.assert_array_equal(a, -b)


def test_fp32_passthrough_identity():
    rng = np.random.default_rng(0)
    x = (rng.standard_normal(4096) * 17.0).astype(np.float32)
    q = q_ref(x, 8, 23)
    np.testing.assert_array_equal(q.view(np.uint32), x.view(np.uint32))


def test_matches_ieee_binary16():
    """S1E5M10 is exactly IEEE half precision (away from inf/NaN)."""
    rng = np.random.default_rng(11)
    x = (rng.standard_normal(65536) * 10).astype(np.float32)
    ours = q_ref(x, 5, 10)
    f16 = x.astype(np.float16).astype(np.float32)
    np.testing.assert_array_equal(ours, f16)


def test_binary16_subnormals():
    """The f16 subnormal grid (multiples of 2^-24) must match exactly."""
    x = (np.arange(-3000, 3000, dtype=np.float32)) * np.float32(2.0**-26)
    ours = q_ref(x, 5, 10)
    f16 = x.astype(np.float16).astype(np.float32)
    np.testing.assert_array_equal(ours, f16)


def test_round_to_nearest_even_ties():
    """Exact ties round to the even mantissa (S1E4M2: grid 1.0, 1.25, 1.5…)."""
    # With m=2, between 1.0 and 1.25 the tie 1.125 -> 1.0 (even), and the
    # tie 1.375 (between 1.25 and 1.5) -> 1.5 (even).
    x = np.array([1.125, 1.375, -1.125, -1.375], np.float32)
    q = q_ref(x, 4, 2)
    np.testing.assert_array_equal(q, [1.0, 1.5, -1.0, -1.5])


@pytest.mark.parametrize("e,m", [(4, 3), (3, 7), (2, 3), (5, 10)])
def test_saturates_to_max_finite(e, m):
    bias = 2 ** (e - 1) - 1
    max_val = (2.0 - 2.0 ** -m) * 2.0 ** bias
    x = np.array([np.inf, -np.inf, 1e30, -1e30, max_val], np.float32)
    q = q_ref(x, e, m)
    np.testing.assert_array_equal(
        q, [max_val, -max_val, max_val, -max_val, max_val])


@pytest.mark.parametrize("e,m", [(3, 7), (2, 3), (4, 8)])
def test_subnormal_grid_is_uniform(e, m):
    """Below the min normal, representables are exact multiples of 2^(1-bias-m)."""
    bias = 2 ** (e - 1) - 1
    quantum = 2.0 ** (1 - bias - m)
    rng = np.random.default_rng(5)
    x = (rng.uniform(-1, 1, 4096) * 2.0 ** (1 - bias)).astype(np.float32)
    q = q_ref(x, e, m).astype(np.float64)
    k = q / quantum
    np.testing.assert_array_equal(k, np.round(k))
    # and the rounding error is at most half a quantum
    assert np.max(np.abs(q - x.astype(np.float64))) <= quantum / 2 + 1e-12


def test_zero_and_tiny_flush():
    q = q_ref(np.array([0.0, -0.0, 1e-42, -1e-42], np.float32), 3, 7)
    np.testing.assert_array_equal(q, [0.0, -0.0, 0.0, -0.0])
    # signs preserved on the zeros
    assert np.signbit(q[1]) and not np.signbit(q[0])


def test_quantization_error_shrinks_with_mantissa_bits():
    rng = np.random.default_rng(9)
    x = (rng.standard_normal(16384) * 0.05).astype(np.float32)
    errs = [np.abs(q_ref(x, 5, m) - x).max() for m in (2, 5, 8, 12, 16)]
    assert all(a >= b for a, b in zip(errs, errs[1:]))


# ---------------------------------------------------------------------------
# Pallas kernel == oracle, bit-exact, across shapes and formats (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=70000),
    e=st.integers(min_value=2, max_value=8),
    m=st.integers(min_value=0, max_value=22),
    scale=st.sampled_from([1e-5, 1e-2, 1.0, 1e3]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pallas_matches_ref_bitexact(n, e, m, scale, seed):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(n) * scale).astype(np.float32)
    a = q_ref(x, e, m)
    b = np.asarray(quant.quantize_pallas(
        jnp.asarray(x), jnp.int32(e), jnp.int32(m)))
    np.testing.assert_array_equal(a.view(np.uint32), b.view(np.uint32))


@settings(max_examples=10, deadline=None)
@given(
    shape=st.sampled_from([(3, 5), (16, 128), (7, 9, 11), (1,), (257, 130)]),
    e=st.integers(min_value=2, max_value=8),
    m=st.integers(min_value=0, max_value=22),
)
def test_pallas_preserves_shape(shape, e, m):
    rng = np.random.default_rng(1)
    x = rng.standard_normal(shape).astype(np.float32)
    out = np.asarray(quant.quantize_pallas(
        jnp.asarray(x), jnp.int32(e), jnp.int32(m)))
    assert out.shape == x.shape
    np.testing.assert_array_equal(out, q_ref(x, e, m))


@pytest.mark.parametrize("block_rows", [8, 64, 256, 1024])
def test_pallas_block_shape_invariance(block_rows):
    """Tile size is a scheduling knob — results must be bit-identical."""
    rng = np.random.default_rng(2)
    x = (rng.standard_normal(50000) * 0.1).astype(np.float32)
    base = q_ref(x, 3, 7)
    out = np.asarray(quant.quantize_pallas(
        jnp.asarray(x), jnp.int32(3), jnp.int32(7), block_rows=block_rows))
    np.testing.assert_array_equal(base.view(np.uint32), out.view(np.uint32))


def test_dispatch_small_and_large_agree():
    rng = np.random.default_rng(6)
    small = (rng.standard_normal(100) * 0.1).astype(np.float32)
    large = (rng.standard_normal(quant.PALLAS_MIN_ELEMS * 2) * 0.1).astype(
        np.float32)
    for x in (small, large):
        out = np.asarray(quant.quantize(jnp.asarray(x), 3, 7))
        np.testing.assert_array_equal(out, q_ref(x, 3, 7))
