"""AOT pipeline smoke: lowering emits parseable HLO text and a manifest that
matches the variable registry. Kept on `tiny` so the pytest cycle stays
fast; the full artifact build is `make artifacts`."""

import json
import os

import pytest
import jax.numpy as jnp

from compile import aot, model as M
from compile.configs import PRESETS


@pytest.fixture(scope="module")
def art(tmp_path_factory):
    root = tmp_path_factory.mktemp("artifacts")
    aot.lower_quant_artifact(str(root))
    man = aot.lower_size("tiny", str(root))
    return root, man


def test_artifact_files_exist(art):
    root, man = art
    for name in ("init", "train_fp32", "train_omc", "train_omc_nopvt",
                 "eval"):
        p = os.path.join(root, "tiny", f"{name}.hlo.txt")
        assert os.path.exists(p), name
        head = open(p).read(200)
        assert head.startswith("HloModule"), name
    assert os.path.exists(os.path.join(root, "quant.hlo.txt"))


def test_manifest_matches_registry(art):
    root, man = art
    on_disk = json.load(open(os.path.join(root, "tiny", "manifest.json")))
    assert on_disk == man
    specs = M.specs(PRESETS["tiny"])
    assert man["num_variables"] == len(specs)
    assert man["total_params"] == sum(s.size for s in specs)
    for entry, s in zip(man["variables"], specs):
        assert entry["name"] == s.name
        assert tuple(entry["shape"]) == tuple(s.shape)
        assert entry["kind"] == s.kind
        assert entry["size"] == s.size


def test_manifest_config_roundtrip(art):
    _, man = art
    cfg = PRESETS["tiny"]
    assert man["config"]["batch"] == cfg.batch
    assert man["config"]["seq_len"] == cfg.seq_len
    assert man["config"]["feature_dim"] == cfg.feature_dim
    assert man["config"]["vocab"] == cfg.vocab
    assert man["config"]["streaming"] == cfg.streaming


def test_hlo_text_has_tuple_root(art):
    """return_tuple=True — the Rust loader unwraps a single tuple."""
    root, _ = art
    text = open(os.path.join(root, "tiny", "eval.hlo.txt")).read()
    assert "ROOT" in text and "tuple" in text


def test_unknown_size_rejected():
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--sizes", "nonexistent"]
    try:
        with pytest.raises(SystemExit):
            aot.main()
    finally:
        sys.argv = argv
