"""L2 model checks: variable registry, shapes, learning, streaming causality,
and the OMC train-step contract the Rust coordinator depends on."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model as M
from compile.configs import PRESETS, ModelConfig
from compile.kernels import ref

CFG = PRESETS["tiny"]


def _params(cfg=CFG, seed=0):
    return M.init_params(cfg, jax.random.PRNGKey(seed))


def _batch(cfg=CFG, seed=1, noise=0.3):
    """Synthetic ASR-like batch: x = E[y] + noise (mirrors data::synth)."""
    rng = np.random.default_rng(seed)
    E = rng.standard_normal((cfg.vocab, cfg.feature_dim)).astype(np.float32)
    y = rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len)).astype(np.int32)
    x = E[y] + noise * rng.standard_normal(
        (cfg.batch, cfg.seq_len, cfg.feature_dim)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(y), E, rng


# ---------------------------------------------------------------------------
# registry / shapes
# ---------------------------------------------------------------------------

def test_specs_unique_names():
    names = [s.name for s in M.specs(CFG)]
    assert len(names) == len(set(names))


def test_specs_param_count_matches_init():
    specs = M.specs(CFG)
    params = _params()
    assert len(params) == len(specs)
    for s, p in zip(specs, params):
        assert tuple(p.shape) == tuple(s.shape), s.name
        assert p.dtype == jnp.float32


def test_weight_matrices_dominate_size():
    """The Sec. 2.4 observation that makes weights-only quantization pay off:
    weight matrices are the overwhelming majority of parameters."""
    specs = M.specs(PRESETS["small"])
    total = sum(s.size for s in specs)
    weights = sum(s.size for s in specs if s.kind == "weight")
    assert weights / total > 0.97


def test_kinds_are_known():
    assert {s.kind for s in M.specs(CFG)} <= {
        "weight", "bias", "norm_scale", "norm_bias"}


def test_forward_shape():
    x, y, _, _ = _batch()
    logits = M.forward(CFG, _params(), x)
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_init_deterministic():
    a = _params(seed=3)
    b = _params(seed=3)
    c = _params(seed=4)
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))
    assert any(not np.array_equal(np.asarray(pa), np.asarray(pc))
               for pa, pc in zip(a, c))


# ---------------------------------------------------------------------------
# learning
# ---------------------------------------------------------------------------

def test_fp32_step_learns():
    train = jax.jit(M.make_train_fp32_fn(CFG))
    p = _params()
    n = len(p)
    rng_losses = []
    x, y, E, rng = _batch()
    for i in range(40):
        yb = rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len)).astype(np.int32)
        xb = E[yb] + 0.3 * rng.standard_normal(
            (CFG.batch, CFG.seq_len, CFG.feature_dim)).astype(np.float32)
        out = train(*p, jnp.asarray(xb), jnp.asarray(yb), jnp.float32(0.1))
        p = list(out[:n])
        rng_losses.append(float(out[-1]))
    assert rng_losses[-1] < rng_losses[0] * 0.7


def test_grad_matches_finite_difference():
    """Spot-check one scalar direction of the autodiff gradient."""
    cfg = CFG
    p = _params()
    x, y, _, _ = _batch()
    loss = lambda plist: M.loss_fn(cfg, plist, x, y)
    g = jax.grad(loss)(p)
    # probe the largest-magnitude gradient entry of the output projection
    gi = np.asarray(g[-2])
    idx = np.unravel_index(np.argmax(np.abs(gi)), gi.shape)
    eps = 1e-3
    def perturbed(delta):
        q = [np.asarray(t).copy() for t in p]
        q[-2][idx] += delta
        return float(loss([jnp.asarray(t) for t in q]))
    fd = (perturbed(eps) - perturbed(-eps)) / (2 * eps)
    assert abs(fd - gi[idx]) < 5e-3 * max(1.0, abs(gi[idx]))


# ---------------------------------------------------------------------------
# streaming (causality)
# ---------------------------------------------------------------------------

def test_streaming_is_causal():
    cfg = ModelConfig(name="t", feature_dim=8, vocab=16, d_model=16,
                      num_heads=2, num_blocks=1, batch=2, seq_len=12,
                      streaming=True)
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    x1 = rng.standard_normal((2, 12, 8)).astype(np.float32)
    x2 = x1.copy()
    x2[:, 8:, :] += 10.0  # perturb the future only
    l1 = np.asarray(M.forward(cfg, p, jnp.asarray(x1)))
    l2 = np.asarray(M.forward(cfg, p, jnp.asarray(x2)))
    np.testing.assert_allclose(l1[:, :8], l2[:, :8], rtol=1e-5, atol=1e-5)
    assert not np.allclose(l1[:, 8:], l2[:, 8:])


def test_non_streaming_uses_future_context():
    cfg = CFG  # streaming=False
    p = _params()
    rng = np.random.default_rng(0)
    x1 = rng.standard_normal((CFG.batch, CFG.seq_len, CFG.feature_dim)).astype(np.float32)
    x2 = x1.copy()
    x2[:, -4:, :] += 10.0
    l1 = np.asarray(M.forward(cfg, p, jnp.asarray(x1)))
    l2 = np.asarray(M.forward(cfg, p, jnp.asarray(x2)))
    assert not np.allclose(l1[:, :4], l2[:, :4])


# ---------------------------------------------------------------------------
# OMC train-step contract (what the Rust coordinator relies on)
# ---------------------------------------------------------------------------

def _omc_state(cfg=CFG):
    specs = M.specs(cfg)
    n = len(specs)
    mask = jnp.asarray(
        [1.0 if s.kind == "weight" else 0.0 for s in specs], jnp.float32)
    return (list(_params(cfg)), jnp.ones((n,), jnp.float32),
            jnp.zeros((n,), jnp.float32), mask, n, specs)


def test_omc_step_outputs_representable():
    t, s, b, mask, n, specs = _omc_state()
    train = jax.jit(M.make_train_omc_fn(CFG, True))
    x, y, _, _ = _batch()
    out = train(*t, s, b, mask, x, y, jnp.float32(0.05),
                jnp.int32(3), jnp.int32(7))
    new_t, new_s, new_b = list(out[:n]), out[n], out[n + 1]
    for i, sp in enumerate(specs):
        tv = np.asarray(new_t[i])
        assert np.all(np.isfinite(tv)), sp.name
        if float(mask[i]) > 0.5:
            rq = np.asarray(ref.quantize_ref(jnp.asarray(tv), 3, 7))
            np.testing.assert_array_equal(
                rq.view(np.uint32), tv.view(np.uint32), err_msg=sp.name)
        else:
            assert float(new_s[i]) == 1.0 and float(new_b[i]) == 0.0, sp.name


def test_omc_fp32format_zero_mask_matches_fp32_step():
    """mask = 0 everywhere: the OMC artifact must reduce to the plain FP32
    step (same semantics, quantization bypassed). Tolerances are a few ulps:
    the two graphs fuse differently under XLA, so bit equality is not
    guaranteed — equivalence is."""
    t, s, b, _, n, _ = _omc_state()
    zero_mask = jnp.zeros((n,), jnp.float32)
    x, y, _, _ = _batch()
    omc_out = jax.jit(M.make_train_omc_fn(CFG, True))(
        *t, s, b, zero_mask, x, y, jnp.float32(0.1),
        jnp.int32(3), jnp.int32(7))
    fp_out = jax.jit(M.make_train_fp32_fn(CFG))(*t, x, y, jnp.float32(0.1))
    for i in range(n):
        np.testing.assert_allclose(
            np.asarray(omc_out[i]), np.asarray(fp_out[i]),
            rtol=1e-5, atol=1e-7)
    assert abs(float(omc_out[n + 2]) - float(fp_out[n])) < 1e-6


def test_omc_nopvt_keeps_identity_transform():
    t, s, b, mask, n, _ = _omc_state()
    train = jax.jit(M.make_train_omc_fn(CFG, use_pvt=False))
    x, y, _, _ = _batch()
    out = train(*t, s, b, mask, x, y, jnp.float32(0.05),
                jnp.int32(3), jnp.int32(7))
    np.testing.assert_array_equal(np.asarray(out[n]), np.ones(n, np.float32))
    np.testing.assert_array_equal(np.asarray(out[n + 1]), np.zeros(n, np.float32))


def test_omc_training_converges_like_fp32():
    """Table-1 shape at tiny scale: OMC @ S1E4M14 tracks the FP32 loss."""
    train_fp = jax.jit(M.make_train_fp32_fn(CFG))
    train_omc = jax.jit(M.make_train_omc_fn(CFG, True))
    t, s, b, mask, n, _ = _omc_state()
    p = [jnp.asarray(np.asarray(v)) for v in t]
    rng = np.random.default_rng(2)
    E = rng.standard_normal((CFG.vocab, CFG.feature_dim)).astype(np.float32)
    lf = lq = None
    for i in range(50):
        yb = rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len)).astype(np.int32)
        xb = (E[yb] + 0.3 * rng.standard_normal(
            (CFG.batch, CFG.seq_len, CFG.feature_dim))).astype(np.float32)
        xb, yb = jnp.asarray(xb), jnp.asarray(yb)
        fo = train_fp(*p, xb, yb, jnp.float32(0.1))
        p, lf = list(fo[:n]), float(fo[-1])
        oo = train_omc(*t, s, b, mask, xb, yb, jnp.float32(0.1),
                       jnp.int32(4), jnp.int32(14))
        t, s, b, lq = list(oo[:n]), oo[n], oo[n + 1], float(oo[n + 2])
    assert lq < 1.15 * lf + 0.05, (lq, lf)


def test_eval_fn_outputs():
    p = _params()
    x, y, _, _ = _batch()
    loss, pred = jax.jit(M.make_eval_fn(CFG))(*p, x, y)
    assert pred.shape == (CFG.batch, CFG.seq_len)
    assert pred.dtype == jnp.int32
    assert np.isfinite(float(loss))
    assert np.all((np.asarray(pred) >= 0) & (np.asarray(pred) < CFG.vocab))
