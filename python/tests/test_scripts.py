"""Unit tests for the CI gate scripts: scripts/check_goldens.py (golden
diff: tolerance edges, missing golden, malformed JSON),
scripts/bench_trend.py (trend gate: thresholds, strict suites, missing
baselines, bless, malformed JSON), and scripts/determinism_check.sh (the
shared four-way byte-determinism engine behind the CI matrix: cmp gate,
liveness greps, RSS ceiling). These run under the existing
``python-tests`` CI job, so a behavior change in any gate fails CI
before it can silently weaken the smoke-goldens or bench-smoke jobs.
"""

import importlib.util
import json
import os
import shutil
import subprocess
import sys
from pathlib import Path

import pytest

SCRIPTS = Path(__file__).resolve().parents[2] / "scripts"


def load_script(name):
    spec = importlib.util.spec_from_file_location(name, SCRIPTS / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


check_goldens = load_script("check_goldens")
bench_trend = load_script("bench_trend")


def run_main(mod, argv, monkeypatch):
    monkeypatch.setattr(sys, "argv", [f"{mod.__name__}.py"] + argv)
    return mod.main()


# ---- check_goldens.walk_diff ----------------------------------------------


def diffs(golden, fresh, rel_tol=1e-6):
    return list(check_goldens.walk_diff(golden, fresh, rel_tol))


def test_walk_diff_integers_are_exact():
    assert diffs({"n": 5}, {"n": 5}) == []
    out = diffs({"n": 5}, {"n": 6})
    assert len(out) == 1
    assert "integer" in out[0][3]


def test_walk_diff_float_tolerance_edges():
    # rel == tol passes (strict > comparison), just above fails
    g, tol = 1.0, 1e-6
    assert diffs({"x": g}, {"x": g * (1 + tol)}, rel_tol=tol * (1 + 1e-9)) == []
    assert diffs({"x": g}, {"x": g * (1 + 3 * tol)}, rel_tol=tol) != []
    # exact equality short-circuits even at rel_tol 0
    assert diffs({"x": 0.25}, {"x": 0.25}, rel_tol=0.0) == []
    # int golden vs float fresh compares numerically, not as a type error
    assert diffs({"x": 1}, {"x": 1.0}) == []
    # NaN (serialized null in our summaries, but guard the numeric path)
    assert diffs({"x": float("nan")}, {"x": float("nan")}) == []


def test_walk_diff_structure_and_type_mismatches():
    assert any("missing key" in d[3] for d in diffs({"a": 1, "b": 2}, {"a": 1}))
    assert any("extra key" in d[3] for d in diffs({"a": 1}, {"a": 1, "b": 2}))
    assert any("array length" in d[3] for d in diffs({"a": [1, 2]}, {"a": [1]}))
    assert any("type mismatch" in d[3] for d in diffs({"a": "1"}, {"a": 1}))
    # bools are not numbers
    assert any("type" in d[3] for d in diffs({"a": True}, {"a": 1}))
    # nested paths are reported
    out = diffs({"a": {"b": [1, 2]}}, {"a": {"b": [1, 3]}})
    assert out and out[0][0] == "$.a.b[1]"


# ---- check_goldens.main ----------------------------------------------------


def write(path, doc):
    path.write_text(json.dumps(doc) if not isinstance(doc, str) else doc)
    return str(path)


def test_check_goldens_match_and_mismatch(tmp_path, monkeypatch):
    fresh = write(tmp_path / "fresh.json", {"cells": [1, 2], "wer": 10.5})
    golden = write(tmp_path / "golden.json", {"cells": [1, 2], "wer": 10.5})
    assert run_main(check_goldens, ["--fresh", fresh, "--golden", golden], monkeypatch) == 0
    bad = write(tmp_path / "bad.json", {"cells": [1, 3], "wer": 10.5})
    assert run_main(check_goldens, ["--fresh", bad, "--golden", golden], monkeypatch) == 1


def test_check_goldens_missing_inputs(tmp_path, monkeypatch):
    fresh = write(tmp_path / "fresh.json", {"a": 1})
    absent = str(tmp_path / "nope.json")
    # missing golden warns by default, fails under --strict-missing
    assert run_main(check_goldens, ["--fresh", fresh, "--golden", absent], monkeypatch) == 0
    assert (
        run_main(
            check_goldens,
            ["--fresh", fresh, "--golden", absent, "--strict-missing"],
            monkeypatch,
        )
        == 1
    )
    # missing fresh summary is a usage error
    assert run_main(check_goldens, ["--fresh", absent, "--golden", fresh], monkeypatch) == 2


def test_check_goldens_malformed_json_is_an_error(tmp_path, monkeypatch):
    fresh = write(tmp_path / "fresh.json", '{"cells": [1,')
    golden = write(tmp_path / "golden.json", {"cells": [1]})
    assert run_main(check_goldens, ["--fresh", fresh, "--golden", golden], monkeypatch) == 2
    assert run_main(check_goldens, ["--fresh", golden, "--golden", fresh], monkeypatch) == 2


def test_check_goldens_schema_bump_requires_bless(tmp_path, monkeypatch, capsys):
    # a schema_version bump (v5 -> v6, the sparse-metrics migration) must
    # hard-FAIL the diff even when every other field matches — the golden
    # was blessed against a different summary shape and has to be
    # re-blessed deliberately, never slide through as field-level chatter
    doc = {"schema_version": 5, "cells": [1, 2], "wer": 10.5}
    golden = write(tmp_path / "golden.json", doc)
    fresh = write(tmp_path / "fresh.json", {**doc, "schema_version": 6})
    rc = run_main(check_goldens, ["--fresh", fresh, "--golden", golden], monkeypatch)
    assert rc == 1
    err = capsys.readouterr().err
    assert "schema_version bumped without --bless" in err
    assert "v5" in err and "v6" in err
    # --bless still copies straight through the pin
    rc = run_main(
        check_goldens, ["--fresh", fresh, "--golden", golden, "--bless"], monkeypatch
    )
    assert rc == 0
    assert json.loads(Path(golden).read_text())["schema_version"] == 6
    # …after which the re-blessed golden matches
    assert run_main(check_goldens, ["--fresh", fresh, "--golden", golden], monkeypatch) == 0
    # same-version documents keep diffing field by field as before
    bad = write(tmp_path / "bad.json", {**doc, "schema_version": 6, "wer": 99.0})
    rc = run_main(check_goldens, ["--fresh", bad, "--golden", golden], monkeypatch)
    assert rc == 1
    assert "GOLDEN MISMATCH" in capsys.readouterr().err
    # documents without the key (unit-test fixtures, older artifacts)
    # never trip the pin
    a = write(tmp_path / "a.json", {"x": 1})
    b = write(tmp_path / "b.json", {"x": 1})
    assert run_main(check_goldens, ["--fresh", a, "--golden", b], monkeypatch) == 0


def test_check_goldens_bless_copies(tmp_path, monkeypatch):
    fresh = write(tmp_path / "fresh.json", {"a": 1})
    golden = tmp_path / "goldens" / "g.json"
    assert (
        run_main(
            check_goldens,
            ["--fresh", fresh, "--golden", str(golden), "--bless"],
            monkeypatch,
        )
        == 0
    )
    assert json.loads(golden.read_text()) == {"a": 1}


# ---- bench_trend -----------------------------------------------------------


def bench_doc(median_by_case):
    return {
        "results": [
            {"name": k, "median_ns": v, "mad_ns": 0.0, "iters": 10}
            for k, v in median_by_case.items()
        ]
    }


def trend_env(tmp_path, fresh, baseline, suite="codec", tag="t0"):
    fresh_dir = tmp_path / tag / "fresh"
    base_dir = tmp_path / tag / "baselines"
    fresh_dir.mkdir(parents=True, exist_ok=True)
    base_dir.mkdir(parents=True, exist_ok=True)
    write(fresh_dir / f"BENCH_{suite}.json", bench_doc(fresh))
    if baseline is not None:
        write(base_dir / f"BENCH_{suite}.json", bench_doc(baseline))
    return ["--dir", str(fresh_dir), "--baselines", str(base_dir)]


def test_bench_trend_within_threshold_passes(tmp_path, monkeypatch):
    argv = trend_env(tmp_path, {"pack": 110.0}, {"pack": 100.0})
    assert run_main(bench_trend, argv, monkeypatch) == 0


def test_bench_trend_regression_warns_without_gate(tmp_path, monkeypatch, capsys):
    argv = trend_env(tmp_path, {"pack": 200.0}, {"pack": 100.0})
    assert run_main(bench_trend, argv, monkeypatch) == 0
    assert "::warning::" in capsys.readouterr().out
    # --strict promotes every suite to a failure
    assert run_main(bench_trend, argv + ["--strict"], monkeypatch) == 1


def test_bench_trend_strict_suites_gate_fails(tmp_path, monkeypatch, capsys):
    argv = trend_env(tmp_path, {"pack": 200.0}, {"pack": 100.0}, suite="codec")
    rc = run_main(
        bench_trend, argv + ["--strict-suites", "codec,pack,round"], monkeypatch
    )
    assert rc == 1
    assert "::error::" in capsys.readouterr().out
    # the same regression in a non-gated suite only warns (the gated suite
    # must still be present — an absent strict suite is itself a failure)
    argv = trend_env(
        tmp_path, {"gemm": 200.0}, {"gemm": 100.0}, suite="native", tag="t1"
    )
    write(Path(argv[1]) / "BENCH_codec.json", bench_doc({"pack": 100.0}))
    write(Path(argv[3]) / "BENCH_codec.json", bench_doc({"pack": 100.0}))
    rc = run_main(bench_trend, argv + ["--strict-suites", "codec"], monkeypatch)
    assert rc == 0
    assert "::warning::" in capsys.readouterr().out


def test_bench_trend_strict_threshold_edges(tmp_path, monkeypatch, capsys):
    gate = ["--strict-suites", "codec", "--strict-threshold", "0.35"]
    # exactly at the threshold passes (strict > comparison)...
    argv = trend_env(tmp_path, {"c": 135.0}, {"c": 100.0}, suite="codec")
    assert run_main(bench_trend, argv + gate, monkeypatch) == 0
    # ...just above fails
    argv = trend_env(tmp_path, {"c": 135.2}, {"c": 100.0}, suite="codec")
    assert run_main(bench_trend, argv + gate, monkeypatch) == 1
    capsys.readouterr()
    # a gated suite between the warn and fail thresholds keeps the
    # ::warning:: tier (a 30% codec slip must not go silent)
    argv = trend_env(
        tmp_path, {"c": 130.0}, {"c": 100.0}, suite="codec", tag="t2"
    )
    assert run_main(bench_trend, argv + gate, monkeypatch) == 0
    assert "::warning::" in capsys.readouterr().out
    # --strict means ANY regression fails — it must tighten gated suites
    # to the lower threshold, not exempt them
    assert run_main(bench_trend, argv + gate + ["--strict"], monkeypatch) == 1


def test_bench_trend_missing_baseline_is_not_a_failure(tmp_path, monkeypatch, capsys):
    argv = trend_env(tmp_path, {"c": 100.0}, None, suite="codec")
    rc = run_main(bench_trend, argv + ["--strict-suites", "codec"], monkeypatch)
    assert rc == 0
    assert "no committed baseline" in capsys.readouterr().out


def test_bench_trend_gated_suite_without_baseline_warns_dormant(
    tmp_path, monkeypatch, capsys
):
    # a strict suite that produced fresh JSON but has no committed baseline
    # (the `population` suite right after it lands) must announce itself as
    # a dormant gate via ::warning::, not fail and not stay silent
    argv = trend_env(tmp_path, {"sample": 100.0}, None, suite="population")
    rc = run_main(
        bench_trend, argv + ["--strict-suites", "codec,population"], monkeypatch
    )
    # codec absent from fresh would fail the absence gate — provide it
    assert rc == 1  # codec has no fresh file in this env
    capsys.readouterr()
    write(Path(argv[1]) / "BENCH_codec.json", bench_doc({"k": 100.0}))
    write(Path(argv[3]) / "BENCH_codec.json", bench_doc({"k": 100.0}))
    rc = run_main(
        bench_trend, argv + ["--strict-suites", "codec,population"], monkeypatch
    )
    assert rc == 0
    out = capsys.readouterr().out
    assert "::warning::" in out and "dormant" in out
    # an ungated suite with a missing baseline keeps the plain note
    argv = trend_env(tmp_path, {"k": 1.0}, None, suite="native", tag="t9")
    rc = run_main(bench_trend, argv, monkeypatch)
    assert rc == 0
    out = capsys.readouterr().out
    assert "no committed baseline" in out and "::warning::" not in out
    # once a baseline is blessed, the same gate arms: a regression fails
    argv = trend_env(
        tmp_path, {"sample": 200.0}, {"sample": 100.0}, suite="population",
        tag="t10",
    )
    rc = run_main(
        bench_trend, argv + ["--strict-suites", "population"], monkeypatch
    )
    assert rc == 1
    assert "::error::" in capsys.readouterr().out


def test_bench_trend_malformed_json_is_an_error(tmp_path, monkeypatch):
    argv = trend_env(tmp_path, {"c": 100.0}, {"c": 100.0}, suite="codec")
    fresh_dir = Path(argv[1])
    (fresh_dir / "BENCH_codec.json").write_text("{not json")
    assert run_main(bench_trend, argv, monkeypatch) == 2
    # a malformed BASELINE is equally fatal for a gated comparison
    (fresh_dir / "BENCH_codec.json").write_text(json.dumps(bench_doc({"c": 1.0})))
    base_dir = Path(argv[3])
    (base_dir / "BENCH_codec.json").write_text("[1, 2]")
    assert run_main(bench_trend, argv, monkeypatch) == 2


def test_bench_trend_bless_and_empty_dir(tmp_path, monkeypatch):
    argv = trend_env(tmp_path, {"c": 123.0}, None, suite="codec")
    assert run_main(bench_trend, argv + ["--bless"], monkeypatch) == 0
    blessed = Path(argv[3]) / "BENCH_codec.json"
    assert json.loads(blessed.read_text())["results"][0]["median_ns"] == 123.0
    # an empty fresh dir is a no-op, not an error
    empty = tmp_path / "empty"
    empty.mkdir()
    assert (
        run_main(
            bench_trend,
            ["--dir", str(empty), "--baselines", str(tmp_path / "b2")],
            monkeypatch,
        )
        == 0
    )


def test_bench_trend_absent_strict_suite_fails(tmp_path, monkeypatch, capsys):
    # only "codec" produced fresh JSON; the gated "round" bench was skipped
    # or crashed — that must FAIL the gate, not silently pass
    argv = trend_env(tmp_path, {"c": 100.0}, {"c": 100.0}, suite="codec")
    rc = run_main(
        bench_trend, argv + ["--strict-suites", "codec,round"], monkeypatch
    )
    assert rc == 1
    out = capsys.readouterr().out
    assert "::error::" in out
    assert "round" in out
    # the present suite with the same gate still passes
    assert run_main(bench_trend, argv + ["--strict-suites", "codec"], monkeypatch) == 0


def test_bench_trend_empty_dir_with_strict_suites_fails(tmp_path, monkeypatch, capsys):
    # an empty fresh dir is a no-op WITHOUT strict suites (covered above),
    # but with a gate it means every gated bench went missing
    empty = tmp_path / "empty"
    empty.mkdir()
    argv = ["--dir", str(empty), "--baselines", str(tmp_path / "b")]
    rc = run_main(bench_trend, argv + ["--strict-suites", "codec,round"], monkeypatch)
    assert rc == 1
    out = capsys.readouterr().out
    # one annotation per absent suite, deterministic order
    assert out.index("'codec'") < out.index("'round'")


def test_bench_trend_bless_ignores_absent_strict_suites(tmp_path, monkeypatch):
    # blessing records whatever ran; the absence gate only guards comparisons
    argv = trend_env(tmp_path, {"c": 123.0}, None, suite="codec")
    rc = run_main(
        bench_trend, argv + ["--strict-suites", "codec,round", "--bless"], monkeypatch
    )
    assert rc == 0
    assert (Path(argv[3]) / "BENCH_codec.json").exists()


def test_bench_trend_delta_suite_is_gated(tmp_path, monkeypatch, capsys):
    # the CI invocation gates the delta wire-stage suite alongside
    # codec/pack/round: a delta kernel regression past the strict
    # threshold must fail, healthy numbers pass, and an absent
    # BENCH_delta.json (skipped or crashed bench) must fail rather than
    # silently drop the suite from the comparison
    gate = [
        "--strict-suites",
        "codec,pack,round,delta",
        "--strict-threshold",
        "0.35",
    ]
    argv = trend_env(tmp_path, {"xor": 200.0}, {"xor": 100.0}, suite="delta")
    for s in ("codec", "pack", "round"):
        write(Path(argv[1]) / f"BENCH_{s}.json", bench_doc({"k": 100.0}))
        write(Path(argv[3]) / f"BENCH_{s}.json", bench_doc({"k": 100.0}))
    assert run_main(bench_trend, argv + gate, monkeypatch) == 1
    assert "::error::" in capsys.readouterr().out
    # healthy delta numbers pass the same four-suite gate
    write(Path(argv[1]) / "BENCH_delta.json", bench_doc({"xor": 105.0}))
    assert run_main(bench_trend, argv + gate, monkeypatch) == 0
    capsys.readouterr()
    # a gated delta bench that produced no fresh JSON is itself a failure
    (Path(argv[1]) / "BENCH_delta.json").unlink()
    assert run_main(bench_trend, argv + gate, monkeypatch) == 1
    assert "'delta'" in capsys.readouterr().out


def test_bench_trend_serve_suite_is_gated_dormant(tmp_path, monkeypatch, capsys):
    # the CI invocation gates the serving-engine suite alongside
    # codec/pack/round/delta/population. Like population when it landed,
    # serve starts dormant: fresh JSON with no committed baseline warns,
    # and the gate arms itself the moment a baseline is blessed
    gate = ["--strict-suites", "codec,serve", "--strict-threshold", "0.35"]
    argv = trend_env(tmp_path, {"serve 6 commits": 100.0}, None, suite="serve")
    write(Path(argv[1]) / "BENCH_codec.json", bench_doc({"k": 100.0}))
    write(Path(argv[3]) / "BENCH_codec.json", bench_doc({"k": 100.0}))
    assert run_main(bench_trend, argv + gate, monkeypatch) == 0
    out = capsys.readouterr().out
    assert "::warning::" in out and "dormant" in out and "serve" in out
    # blessed baseline + regression -> the armed gate fails
    argv = trend_env(
        tmp_path,
        {"serve 6 commits": 200.0},
        {"serve 6 commits": 100.0},
        suite="serve",
        tag="t11",
    )
    assert run_main(bench_trend, argv + ["--strict-suites", "serve"], monkeypatch) == 1
    assert "::error::" in capsys.readouterr().out


def test_bench_trend_sparse_suite_is_gated_dormant(tmp_path, monkeypatch, capsys):
    # the CI invocation now gates the sparse uplink suite alongside
    # codec/pack/round/delta/population/serve. Like those before it,
    # sparse starts dormant: fresh JSON with no committed baseline warns,
    # a gated sparse bench that never ran fails, and the gate arms the
    # moment a baseline is blessed
    gate = ["--strict-suites", "codec,sparse", "--strict-threshold", "0.35"]
    argv = trend_env(tmp_path, {"select_topk 1%": 100.0}, None, suite="sparse")
    write(Path(argv[1]) / "BENCH_codec.json", bench_doc({"k": 100.0}))
    write(Path(argv[3]) / "BENCH_codec.json", bench_doc({"k": 100.0}))
    assert run_main(bench_trend, argv + gate, monkeypatch) == 0
    out = capsys.readouterr().out
    assert "::warning::" in out and "dormant" in out and "sparse" in out
    # a gated sparse bench with no fresh JSON (skipped or crashed) fails
    (Path(argv[1]) / "BENCH_sparse.json").unlink()
    assert run_main(bench_trend, argv + gate, monkeypatch) == 1
    assert "'sparse'" in capsys.readouterr().out
    # blessed baseline + regression -> the armed gate fails
    argv = trend_env(
        tmp_path,
        {"select_topk 1%": 200.0},
        {"select_topk 1%": 100.0},
        suite="sparse",
        tag="t12",
    )
    assert run_main(bench_trend, argv + ["--strict-suites", "sparse"], monkeypatch) == 1
    assert "::error::" in capsys.readouterr().out


def test_bench_trend_cold_path_median_demotes_the_gate(tmp_path, monkeypatch, capsys):
    # under OMC_BENCH_FAST some suites emit rows whose measured iters fall
    # below warmup_iters — a cold-path median. Such a row regressing past
    # the strict threshold must demote the gate to a ::warning:: (the
    # statistic is not comparable), while a steady row with the identical
    # ratio keeps failing
    def doc(median, iters, warmup):
        return {
            "results": [
                {
                    "name": "pack",
                    "median_ns": median,
                    "mad_ns": 0.0,
                    "iters": iters,
                    "warmup_iters": warmup,
                }
            ]
        }

    gate = ["--strict-suites", "codec", "--strict-threshold", "0.35"]
    fresh_dir = tmp_path / "fresh"
    base_dir = tmp_path / "baselines"
    fresh_dir.mkdir()
    base_dir.mkdir()
    argv = ["--dir", str(fresh_dir), "--baselines", str(base_dir)]
    write(base_dir / "BENCH_codec.json", doc(100.0, 20, 8))
    # cold fresh row (iters 3 < warmup 8), 2x regression: warn, exit 0
    write(fresh_dir / "BENCH_codec.json", doc(200.0, 3, 8))
    assert run_main(bench_trend, argv + gate, monkeypatch) == 0
    out = capsys.readouterr().out
    assert "::warning::" in out and "cold-path median" in out
    assert "::error::" not in out
    # the same regression measured at steady state fails the gate
    write(fresh_dir / "BENCH_codec.json", doc(200.0, 20, 8))
    assert run_main(bench_trend, argv + gate, monkeypatch) == 1
    assert "::error::" in capsys.readouterr().out
    # a cold BASELINE row demotes too — either side disqualifies the pair
    write(base_dir / "BENCH_codec.json", doc(100.0, 2, 8))
    write(fresh_dir / "BENCH_codec.json", doc(200.0, 20, 8))
    assert run_main(bench_trend, argv + gate, monkeypatch) == 0
    assert "cold-path median" in capsys.readouterr().out
    # rows missing the fields entirely (older baselines) count as steady:
    # the bench_doc helper omits warmup_iters, and the gate still fails
    write(base_dir / "BENCH_codec.json", bench_doc({"pack": 100.0}))
    write(fresh_dir / "BENCH_codec.json", bench_doc({"pack": 200.0}))
    assert run_main(bench_trend, argv + gate, monkeypatch) == 1
    assert "::error::" in capsys.readouterr().out


def test_bench_capture_covers_every_bench_target():
    # bench_capture.sh is how baselines get blessed; a [[bench]] target it
    # does not run can never arm its trend gate (the gap that left delta
    # and population baselines uncapturable)
    root = SCRIPTS.parent
    cargo = (root / "Cargo.toml").read_text()
    capture = (root / "scripts" / "bench_capture.sh").read_text()
    targets = [
        line.split('"')[1]
        for line in cargo.splitlines()
        if line.startswith('name = "bench_')
    ]
    assert targets, "no [[bench]] targets parsed from Cargo.toml"
    missing = [t for t in targets if t not in capture]
    assert not missing, f"bench_capture.sh never runs: {missing}"


def test_bench_trend_suite_name_parsing():
    assert bench_trend.suite_name("BENCH_codec.json") == "codec"
    assert bench_trend.suite_name("/tmp/x/BENCH_round.json") == "round"
    assert bench_trend.suite_name("other.json") == "other.json"


# ---- determinism_check.sh --------------------------------------------------

DET_CHECK = SCRIPTS / "determinism_check.sh"
BASH = shutil.which("bash")

pytestmark_sh = pytest.mark.skipif(BASH is None, reason="bash unavailable")

# a stand-in sweep binary: every invocation writes $STUB_SUMMARY as the
# summary (plus a timing file, like the real engine). With STUB_COUNTER
# set, it appends a per-invocation counter — deliberate nondeterminism.
STUB_BIN = """#!/usr/bin/env bash
out=
while [ $# -gt 0 ]; do
  case $1 in
    --out) out=$2; shift 2 ;;
    *) shift ;;
  esac
done
mkdir -p "$out"
body="$STUB_SUMMARY"
if [ -n "${STUB_COUNTER:-}" ]; then
  n=$(cat "$STUB_COUNTER" 2>/dev/null || echo 0)
  n=$((n + 1))
  echo "$n" > "$STUB_COUNTER"
  body="$body run=$n"
fi
printf '%s' "$body" > "$out/sweep_summary.json"
printf '{"wall_s":1}' > "$out/sweep_timing.json"
"""

# a stand-in GNU time: reports $STUB_RSS_KB as peak RSS on stderr (which
# the gate captures to its log file), then runs the real command
STUB_TIME = """#!/usr/bin/env bash
shift  # -v
echo "\tMaximum resident set size (kbytes): $STUB_RSS_KB" >&2
exec "$@"
"""

# a stand-in BSD/macOS time: rejects GNU's -v (so the gate's dialect
# probe must fall back), accepts -l, and reports peak RSS in BYTES with
# the value-first layout `/usr/bin/time -l` uses
STUB_TIME_BSD = """#!/usr/bin/env bash
if [ "$1" = "-v" ]; then
  echo "stub-bsd-time: illegal option -- v" >&2
  exit 1
fi
shift  # -l
echo "  $STUB_RSS_BYTES  maximum resident set size" >&2
exec "$@"
"""

# a time binary that speaks neither dialect
STUB_TIME_NONE = """#!/usr/bin/env bash
exit 1
"""


def det_check(tmp_path, *greps, summary='{"x":1}', env=None):
    """Run determinism_check.sh against the stub binary; return the
    CompletedProcess."""
    stub = tmp_path / "stub-omc-fl"
    stub.write_text(STUB_BIN)
    stub.chmod(0o755)
    full_env = {
        **os.environ,
        "OMC_BIN": str(stub),
        "STUB_SUMMARY": summary,
        **(env or {}),
    }
    return subprocess.run(
        [BASH, str(DET_CHECK), "smoke-test", str(tmp_path / "out")] + list(greps),
        capture_output=True,
        text=True,
        env=full_env,
        cwd=tmp_path,
    )


@pytestmark_sh
def test_determinism_check_passes_and_writes_four_variants(tmp_path):
    r = det_check(tmp_path, summary='{"churn_rejections":7}')
    assert r.returncode == 0, r.stdout + r.stderr
    assert "byte-identical" in r.stdout
    for variant in ("seq_a", "seq_b", "pool", "scalar"):
        d = tmp_path / f"out_{variant}"
        assert (d / "sweep_summary.json").is_file()
        assert (d / "sweep_timing.json").is_file()


@pytestmark_sh
def test_determinism_check_liveness_greps(tmp_path):
    # matching counters pass and are reported
    r = det_check(
        tmp_path,
        '"churn_rejections":[1-9]',
        '"wave_rejections":[1-9]',
        summary='{"churn_rejections":7,"wave_rejections":3}',
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "2 liveness counters nonzero" in r.stdout
    # a silent zero fails with an ::error:: naming the dead pattern
    r = det_check(
        tmp_path,
        '"wave_rejections":[1-9]',
        summary='{"churn_rejections":7,"wave_rejections":0}',
    )
    assert r.returncode == 1
    assert "::error::" in r.stdout and "wave_rejections" in r.stdout


@pytestmark_sh
def test_determinism_check_catches_nondeterminism(tmp_path):
    # the stub varies its summary per invocation — cmp must catch it
    r = det_check(
        tmp_path, env={"STUB_COUNTER": str(tmp_path / "counter")}
    )
    assert r.returncode == 1
    assert "differs" in r.stdout


@pytestmark_sh
def test_determinism_check_usage_error(tmp_path):
    r = subprocess.run(
        [BASH, str(DET_CHECK), "only-profile"],
        capture_output=True,
        text=True,
        cwd=tmp_path,
    )
    assert r.returncode == 2
    assert "usage:" in r.stderr


@pytestmark_sh
def test_determinism_check_rss_ceiling(tmp_path):
    # the O(active) gate: peak RSS under the ceiling passes...
    stub_time = tmp_path / "stub-time"
    stub_time.write_text(STUB_TIME)
    stub_time.chmod(0o755)
    env = {"OMC_TIME_BIN": str(stub_time), "OMC_RSS_CEILING_MB": "400"}
    r = det_check(tmp_path, env={**env, "STUB_RSS_KB": "100000"})
    assert r.returncode == 0, r.stdout + r.stderr
    assert "peak RSS 100000 kB" in r.stdout
    # ...and a fleet-sized blowup past the ceiling fails
    r = det_check(tmp_path, env={**env, "STUB_RSS_KB": "900000"})
    assert r.returncode == 1
    assert "::error::" in r.stdout and "ceiling" in r.stdout
    # a time binary that is absent must FAIL — a requested ceiling the
    # gate cannot meter would otherwise void the memory contract silently
    r = det_check(
        tmp_path,
        env={
            "OMC_TIME_BIN": str(tmp_path / "no-such-time"),
            "OMC_RSS_CEILING_MB": "400",
        },
    )
    assert r.returncode == 1
    assert "::error::" in r.stdout and "cannot be enforced" in r.stdout


@pytestmark_sh
def test_determinism_check_rss_bsd_fallback(tmp_path):
    # a BSD/macOS time binary (no -v, value-first -l output in bytes):
    # the gate must fall back, convert bytes -> kB, and enforce the same
    # ceiling — previously this host silently skipped the check
    stub_time = tmp_path / "stub-bsd-time"
    stub_time.write_text(STUB_TIME_BSD)
    stub_time.chmod(0o755)
    env = {"OMC_TIME_BIN": str(stub_time), "OMC_RSS_CEILING_MB": "400"}
    # 100000 kB worth of bytes stays under the 400 MB ceiling
    r = det_check(tmp_path, env={**env, "STUB_RSS_BYTES": str(100000 * 1024)})
    assert r.returncode == 0, r.stdout + r.stderr
    assert "peak RSS 100000 kB" in r.stdout
    # ...and a blowup past the ceiling fails through the same fallback
    r = det_check(tmp_path, env={**env, "STUB_RSS_BYTES": str(900000 * 1024)})
    assert r.returncode == 1
    assert "::error::" in r.stdout and "ceiling" in r.stdout


@pytestmark_sh
def test_determinism_check_rss_unmeterable_hosts_fail_loudly(tmp_path):
    # neither GNU -v nor BSD -l: the probe must refuse to run unmetered
    stub_time = tmp_path / "stub-none-time"
    stub_time.write_text(STUB_TIME_NONE)
    stub_time.chmod(0o755)
    r = det_check(
        tmp_path,
        env={"OMC_TIME_BIN": str(stub_time), "OMC_RSS_CEILING_MB": "400"},
    )
    assert r.returncode == 1
    assert "::error::" in r.stdout and "neither GNU -v nor BSD -l" in r.stdout
    # a dialect that probes fine but emits no RSS line is equally fatal
    gnu = tmp_path / "stub-gnu-time"
    gnu.write_text(STUB_TIME)  # with STUB_RSS_KB unset the value is empty
    gnu.chmod(0o755)
    r = det_check(
        tmp_path,
        env={"OMC_TIME_BIN": str(gnu), "OMC_RSS_CEILING_MB": "400"},
    )
    assert r.returncode == 1
    assert "::error::" in r.stdout and "no RSS line" in r.stdout


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-q"]))
