"""Per-variable transformation (Sec. 2.3): least-squares optimality,
degenerate cases, and the end-to-end error-reduction claim."""

import numpy as np
import pytest
import jax.numpy as jnp
# hypothesis is absent from the offline image; skip (not error) the
# property tests there so the rest of the suite still runs
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def fit(v, vt):
    s, b = ref.pvt_fit_ref(jnp.asarray(v), jnp.asarray(vt))
    return float(s), float(b)


def mse(v, dec):
    return float(np.mean((v.astype(np.float64) - dec.astype(np.float64)) ** 2))


def test_exact_affine_recovery():
    """If vt is an exact affine image of v, PVT must invert it (up to f32)."""
    rng = np.random.default_rng(0)
    v = rng.standard_normal(4096).astype(np.float32)
    vt = ((v - 0.25) / 2.0).astype(np.float32)
    s, b = fit(v, vt)
    assert abs(s - 2.0) < 1e-5
    assert abs(b - 0.25) < 1e-5


def test_least_squares_optimality():
    """Perturbing (s, b) in any direction must not reduce the MSE."""
    rng = np.random.default_rng(1)
    v = (rng.standard_normal(8192) * 0.05).astype(np.float32)
    vt = np.asarray(ref.quantize_ref(jnp.asarray(v), 2, 3))
    s, b = fit(v, vt)
    best = mse(v, s * vt + b)
    for ds, db in [(1e-3, 0), (-1e-3, 0), (0, 1e-4), (0, -1e-4),
                   (1e-3, 1e-4), (-1e-3, -1e-4)]:
        assert mse(v, (s + ds) * vt + (b + db)) >= best - 1e-15


def test_degenerate_constant_vt():
    """vt constant => denominator 0 => s = 1, b = mean(v - vt)."""
    v = np.array([1.0, 2.0, 3.0, 4.0], np.float32)
    vt = np.full(4, 2.0, np.float32)
    s, b = fit(v, vt)
    assert s == 1.0
    assert abs(b - (np.mean(v) - 2.0)) < 1e-6


def test_degenerate_all_zero():
    v = np.zeros(16, np.float32)
    vt = np.zeros(16, np.float32)
    s, b = fit(v, vt)
    assert s == 1.0 and b == 0.0


def test_single_element():
    s, b = fit(np.array([3.0], np.float32), np.array([2.0], np.float32))
    assert s == 1.0          # n=1 denominator is 0
    assert abs(b - 1.0) < 1e-6


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=20000),
    e=st.integers(min_value=2, max_value=6),
    m=st.integers(min_value=0, max_value=14),
    scale=st.sampled_from([1e-3, 0.05, 1.0]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pvt_never_hurts(n, e, m, scale, seed):
    """The paper's rationale: decompressed-with-PVT is at least as close to V
    as raw dequantization (least squares includes (s,b) = (1,0))."""
    rng = np.random.default_rng(seed)
    v = (rng.standard_normal(n) * scale).astype(np.float32)
    vt = np.asarray(ref.quantize_ref(jnp.asarray(v), e, m))
    s, b = fit(v, vt)
    # compare in f64 with the fitted f32 scalars, matching the wire contract
    assert mse(v, np.float32(s) * vt + np.float32(b)) <= mse(v, vt) + 1e-12


def test_fakequant_pvt_composition():
    rng = np.random.default_rng(4)
    v = (rng.standard_normal(4096) * 0.02).astype(np.float32)
    vt, s, b = ref.fakequant_pvt_ref(jnp.asarray(v), 3, 7)
    vt = np.asarray(vt)
    # vt is exactly representable
    rq = np.asarray(ref.quantize_ref(jnp.asarray(vt), 3, 7))
    np.testing.assert_array_equal(rq.view(np.uint32), vt.view(np.uint32))
    # decompression improves on raw dequantization
    dec = np.asarray(ref.decompress_ref(jnp.asarray(vt), s, b))
    assert mse(v, dec) <= mse(v, vt) + 1e-12


def test_scalars_are_f32():
    rng = np.random.default_rng(8)
    v = (rng.standard_normal(1024) * 0.1).astype(np.float32)
    vt, s, b = ref.fakequant_pvt_ref(jnp.asarray(v), 4, 8)
    assert s.dtype == jnp.float32 and b.dtype == jnp.float32


def test_f64_accumulation_beats_f32_on_large_offsets():
    """The fit must stay accurate when sums cancel badly — the reason the
    paper computes s and b in 64-bit."""
    rng = np.random.default_rng(10)
    v = (rng.standard_normal(100000) * 1e-3 + 100.0).astype(np.float32)
    vt = np.asarray(ref.quantize_ref(jnp.asarray(v), 5, 10))
    s, b = fit(v, vt)
    dec = np.float32(s) * vt + np.float32(b)
    assert mse(v, dec) <= mse(v, vt) + 1e-12
    assert np.isfinite(s) and np.isfinite(b)
