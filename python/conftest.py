"""pytest bootstrap: make ``compile`` importable and enable x64 before any
jax op runs (the PVT fit accumulates in f64, Sec. 2.3 of the paper)."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)
